//! FFT plans: cached radix-2 and Bluestein transforms, plus 2-D plans.
//!
//! Plans are immutable after construction and are shared via
//! [`Arc`](std::sync::Arc) through the [`Planner`](super::Planner)
//! cache.  The `*_scratch` transform variants let hot paths reuse a
//! caller-owned convolution buffer so Bluestein-length transforms run
//! allocation-free (the plain `forward`/`inverse` keep the old
//! behaviour: radix-2 never allocates, Bluestein allocates its
//! convolution buffer per call).

use super::complex::Complex;
use super::planner::Planner;
use std::sync::Arc;

/// A reusable 1-D FFT plan for a fixed length.
///
/// Power-of-two lengths use iterative radix-2 Cooley–Tukey with cached
/// twiddles and a cached bit-reversal permutation.  Other lengths use
/// Bluestein's chirp-z algorithm, re-expressing the DFT as a cyclic
/// convolution of power-of-two length (whose plan is cached inside).
pub struct Plan {
    n: usize,
    kind: Kind,
}

enum Kind {
    /// n == 0 or 1.
    Trivial,
    Radix2 {
        /// twiddle[s] holds the stage-s factors, total n/2 per direction.
        twiddles_fwd: Vec<Complex>,
        twiddles_inv: Vec<Complex>,
        bitrev: Vec<u32>,
    },
    Bluestein {
        /// chirp[k] = e^{-iπk²/n}
        chirp: Vec<Complex>,
        /// FFT(b) where b[k] = conj(chirp[k]) arranged cyclically, length m.
        bhat_fwd: Vec<Complex>,
        m: usize,
        inner: Box<Plan>,
    },
}

impl Plan {
    /// Build a plan for length `n`.
    pub fn new(n: usize) -> Self {
        if n <= 1 {
            return Self { n, kind: Kind::Trivial };
        }
        if n.is_power_of_two() {
            Self {
                n,
                kind: build_radix2(n),
            }
        } else {
            Self {
                n,
                kind: build_bluestein(n),
            }
        }
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for the degenerate 0/1-length plan.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward transform. Panics if `data.len() != self.len()`.
    pub fn forward(&self, data: &mut [Complex]) {
        self.forward_scratch(data, &mut Vec::new());
    }

    /// In-place inverse transform (scaled by 1/N).
    pub fn inverse(&self, data: &mut [Complex]) {
        self.inverse_scratch(data, &mut Vec::new());
    }

    /// Forward transform reusing `scratch` for the Bluestein
    /// convolution buffer (untouched on radix-2 lengths) — zero
    /// allocations once `scratch` has warmed up to capacity.
    pub fn forward_scratch(&self, data: &mut [Complex], scratch: &mut Vec<Complex>) {
        assert_eq!(data.len(), self.n, "plan length mismatch");
        self.run(data, false, scratch);
    }

    /// Inverse transform (scaled by 1/N) reusing `scratch` like
    /// [`forward_scratch`](Self::forward_scratch).
    pub fn inverse_scratch(&self, data: &mut [Complex], scratch: &mut Vec<Complex>) {
        assert_eq!(data.len(), self.n, "plan length mismatch");
        self.run(data, true, scratch);
        let k = 1.0 / self.n as f64;
        for c in data.iter_mut() {
            *c = c.scale(k);
        }
    }

    /// Unscaled transform core.
    fn run(&self, data: &mut [Complex], inverse: bool, scratch: &mut Vec<Complex>) {
        match &self.kind {
            Kind::Trivial => {}
            Kind::Radix2 {
                twiddles_fwd,
                twiddles_inv,
                bitrev,
            } => {
                let tw = if inverse { twiddles_inv } else { twiddles_fwd };
                radix2_inplace(data, tw, bitrev);
            }
            Kind::Bluestein {
                chirp,
                bhat_fwd,
                m,
                inner,
            } => {
                bluestein(data, chirp, bhat_fwd, *m, inner, inverse, scratch);
            }
        }
    }
}

fn build_radix2(n: usize) -> Kind {
    debug_assert!(n.is_power_of_two() && n >= 2);
    // Stage-ordered twiddles: for stage half-size h = 1,2,4,...,n/2 store
    // w^j for j in 0..h with w = e^{∓2πi/(2h)}. Total n-1 entries.
    let mut twiddles_fwd = Vec::with_capacity(n - 1);
    let mut twiddles_inv = Vec::with_capacity(n - 1);
    let mut h = 1usize;
    while h < n {
        for j in 0..h {
            let ang = std::f64::consts::PI * (j as f64) / (h as f64);
            twiddles_fwd.push(Complex::from_polar(1.0, -ang));
            twiddles_inv.push(Complex::from_polar(1.0, ang));
        }
        h *= 2;
    }
    let bits = n.trailing_zeros();
    let bitrev = (0..n as u32)
        .map(|i| i.reverse_bits() >> (32 - bits))
        .collect();
    Kind::Radix2 {
        twiddles_fwd,
        twiddles_inv,
        bitrev,
    }
}

/// Iterative in-place radix-2 with pre-permuted input ordering.
fn radix2_inplace(data: &mut [Complex], twiddles: &[Complex], bitrev: &[u32]) {
    let n = data.len();
    // Bit-reversal permutation.
    for i in 0..n {
        let j = bitrev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    // Butterflies.
    let mut h = 1usize;
    let mut tw_base = 0usize;
    while h < n {
        let step = 2 * h;
        let tw = &twiddles[tw_base..tw_base + h];
        let mut start = 0;
        while start < n {
            for j in 0..h {
                let u = data[start + j];
                let v = data[start + j + h] * tw[j];
                data[start + j] = u + v;
                data[start + j + h] = u - v;
            }
            start += step;
        }
        tw_base += h;
        h = step;
    }
}

fn build_bluestein(n: usize) -> Kind {
    // Chirp c[k] = e^{-iπ k²/n}; indices mod 2n for numerical stability.
    let chirp: Vec<Complex> = (0..n)
        .map(|k| {
            let kk = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
            Complex::from_polar(1.0, -std::f64::consts::PI * kk / n as f64)
        })
        .collect();
    let m = (2 * n - 1).next_power_of_two();
    let inner = Plan::new(m);
    // b[j] = conj(chirp[|j|]) cyclically embedded in length m.
    let mut b = vec![Complex::ZERO; m];
    b[0] = chirp[0].conj();
    for j in 1..n {
        let v = chirp[j].conj();
        b[j] = v;
        b[m - j] = v;
    }
    inner.forward(&mut b);
    Kind::Bluestein {
        chirp,
        bhat_fwd: b,
        m,
        inner: Box::new(inner),
    }
}

fn bluestein(
    data: &mut [Complex],
    chirp: &[Complex],
    bhat: &[Complex],
    m: usize,
    inner: &Plan,
    inverse: bool,
    scratch: &mut Vec<Complex>,
) {
    let n = data.len();
    // For the inverse direction, conjugate in, conjugate out (1/N scaling
    // applied by the caller).  The convolution buffer is caller-owned so
    // repeated transforms through one plan are allocation-free; the tail
    // beyond n must be re-zeroed because the buffer is reused.
    scratch.resize(m, Complex::ZERO);
    let a = &mut scratch[..];
    for k in 0..n {
        let x = if inverse { data[k].conj() } else { data[k] };
        a[k] = x * chirp[k];
    }
    for ai in a[n..].iter_mut() {
        *ai = Complex::ZERO;
    }
    inner.forward(a);
    for (ai, bi) in a.iter_mut().zip(bhat.iter()) {
        *ai = *ai * *bi;
    }
    inner.inverse(a);
    for k in 0..n {
        let y = a[k] * chirp[k];
        data[k] = if inverse { y.conj() } else { y };
    }
}

/// A full-complex 2-D FFT plan over row-major `rows × cols` data.
///
/// The signal-simulation "FT" step transforms the (channel × tick) grid;
/// rows are channels (wire/pitch axis ω_x) and columns ticks (ω_t).
/// The production FT path is the half-spectrum
/// [`Fft2dReal`](super::Fft2dReal); this full-complex plan remains as
/// the general tool and as the `apply_reference` baseline the spectral
/// bench gates against.  The 1-D plans are `Arc`-shared through a
/// [`Planner`], so two 2-D plans over the same lengths reuse one set of
/// twiddle/bit-reversal tables.
#[derive(Clone)]
pub struct Fft2d {
    rows: usize,
    cols: usize,
    row_plan: Arc<Plan>,
    col_plan: Arc<Plan>,
}

impl Fft2d {
    /// Build a 2-D plan with 1-D plans from the process-wide
    /// [`Planner`] cache.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self::with_planner(rows, cols, &Planner::shared())
    }

    /// Build a 2-D plan sharing 1-D plans through `planner`.
    pub fn with_planner(rows: usize, cols: usize, planner: &Arc<Planner>) -> Self {
        Self {
            rows,
            cols,
            row_plan: planner.plan(cols),
            col_plan: planner.plan(rows),
        }
    }

    /// Grid shape (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// In-place forward 2-D transform of row-major data.
    pub fn forward(&self, data: &mut [Complex]) {
        self.transform(data, false);
    }

    /// In-place inverse 2-D transform (scaled by 1/(rows·cols)).
    pub fn inverse(&self, data: &mut [Complex]) {
        self.transform(data, true);
    }

    fn transform(&self, data: &mut [Complex], inverse: bool) {
        assert_eq!(data.len(), self.rows * self.cols, "grid shape mismatch");
        // Rows first.
        for r in 0..self.rows {
            let row = &mut data[r * self.cols..(r + 1) * self.cols];
            if inverse {
                self.row_plan.inverse(row);
            } else {
                self.row_plan.forward(row);
            }
        }
        // Then columns, via a scratch column buffer.
        let mut col = vec![Complex::ZERO; self.rows];
        for c in 0..self.cols {
            for r in 0..self.rows {
                col[r] = data[r * self.cols + c];
            }
            if inverse {
                self.col_plan.inverse(&mut col);
            } else {
                self.col_plan.forward(&mut col);
            }
            for r in 0..self.rows {
                data[r * self.cols + c] = col[r];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft_naive, Direction};

    #[test]
    fn plan_reuse_matches_oneshot() {
        let plan = Plan::new(128);
        for trial in 0..3 {
            let input: Vec<Complex> = (0..128)
                .map(|i| Complex::new((i + trial) as f64, -(i as f64) * 0.25))
                .collect();
            let mut a = input.clone();
            plan.forward(&mut a);
            let b = dft_naive(&input, Direction::Forward);
            for (x, y) in a.iter().zip(&b) {
                assert!((x.re - y.re).abs() < 1e-7 && (x.im - y.im).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn bluestein_prime_length() {
        let n = 97;
        let input: Vec<Complex> = (0..n).map(|i| Complex::new((i % 7) as f64, (i % 3) as f64)).collect();
        let mut fast = input.clone();
        Plan::new(n).forward(&mut fast);
        let slow = dft_naive(&input, Direction::Forward);
        for (x, y) in fast.iter().zip(&slow) {
            assert!((x.re - y.re).abs() < 1e-7 && (x.im - y.im).abs() < 1e-7);
        }
    }

    #[test]
    #[should_panic(expected = "plan length mismatch")]
    fn wrong_length_panics() {
        let plan = Plan::new(8);
        let mut buf = vec![Complex::ZERO; 4];
        plan.forward(&mut buf);
    }

    #[test]
    fn fft2d_roundtrip() {
        let (r, c) = (6, 10); // exercises Bluestein rows and radix-2-ish cols
        let input: Vec<Complex> = (0..r * c).map(|i| Complex::new(i as f64, (i % 5) as f64)).collect();
        let plan = Fft2d::new(r, c);
        let mut buf = input.clone();
        plan.forward(&mut buf);
        plan.inverse(&mut buf);
        for (x, y) in buf.iter().zip(&input) {
            assert!((x.re - y.re).abs() < 1e-8 && (x.im - y.im).abs() < 1e-8);
        }
    }

    #[test]
    fn fft2d_matches_separable_naive() {
        let (r, c) = (4, 3);
        let input: Vec<Complex> = (0..r * c).map(|i| Complex::new((i * i % 11) as f64, 0.0)).collect();
        // naive 2-D dft
        let mut expect = vec![Complex::ZERO; r * c];
        for kr in 0..r {
            for kc in 0..c {
                let mut acc = Complex::ZERO;
                for jr in 0..r {
                    for jc in 0..c {
                        let ang = -2.0 * std::f64::consts::PI
                            * ((kr * jr) as f64 / r as f64 + (kc * jc) as f64 / c as f64);
                        acc += input[jr * c + jc] * Complex::from_polar(1.0, ang);
                    }
                }
                expect[kr * c + kc] = acc;
            }
        }
        let mut fast = input.clone();
        Fft2d::new(r, c).forward(&mut fast);
        for (x, y) in fast.iter().zip(&expect) {
            assert!((x.re - y.re).abs() < 1e-8 && (x.im - y.im).abs() < 1e-8);
        }
    }

    #[test]
    fn fft2d_dc_component() {
        let (r, c) = (8, 8);
        let input = vec![Complex::ONE; r * c];
        let mut buf = input;
        Fft2d::new(r, c).forward(&mut buf);
        assert!((buf[0].re - 64.0).abs() < 1e-9);
        for (i, z) in buf.iter().enumerate().skip(1) {
            assert!(z.abs() < 1e-9, "bin {i} = {z:?}");
        }
    }
}
