//! Minimal complex-number type for the FFT substrate.
//!
//! `num-complex` is not in the vendored registry, and the FFT is the only
//! consumer of complex arithmetic, so we keep a purpose-built `f64` pair
//! with exactly the operations the transforms and the response assembly
//! need.

use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number with `f64` parts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl Complex {
    /// Additive identity.
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    /// Multiplicative identity.
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };
    /// The imaginary unit.
    pub const I: Complex = Complex { re: 0.0, im: 1.0 };

    /// Construct from parts.
    #[inline]
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// Construct from a real value.
    #[inline]
    pub const fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// r·e^{iθ}.
    #[inline]
    pub fn from_polar(r: f64, theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Self::new(r * c, r * s)
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        Self::new(self.re, -self.im)
    }

    /// |z|².
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// |z|.
    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Argument in (-π, π].
    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, k: f64) -> Self {
        Self::new(self.re * k, self.im * k)
    }

    /// Multiplicative inverse (panics on zero in debug builds).
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.norm_sqr();
        debug_assert!(d > 0.0, "inverse of zero");
        Self::new(self.re / d, -self.im / d)
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, rhs: Complex) -> Complex {
        Complex::new(self.re + rhs.re, self.im + rhs.im)
    }
}

impl AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, rhs: Complex) {
        self.re += rhs.re;
        self.im += rhs.im;
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, rhs: Complex) -> Complex {
        Complex::new(self.re - rhs.re, self.im - rhs.im)
    }
}

impl SubAssign for Complex {
    #[inline]
    fn sub_assign(&mut self, rhs: Complex) {
        self.re -= rhs.re;
        self.im -= rhs.im;
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, rhs: Complex) -> Complex {
        Complex::new(
            self.re * rhs.re - self.im * rhs.im,
            self.re * rhs.im + self.im * rhs.re,
        )
    }
}

impl MulAssign for Complex {
    #[inline]
    fn mul_assign(&mut self, rhs: Complex) {
        *self = *self * rhs;
    }
}

impl Mul<f64> for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, k: f64) -> Complex {
        self.scale(k)
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex::new(-self.re, -self.im)
    }
}

impl From<f64> for Complex {
    #[inline]
    fn from(re: f64) -> Self {
        Complex::real(re)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let z = Complex::new(3.0, -4.0);
        assert_eq!(z + Complex::ZERO, z);
        assert_eq!(z * Complex::ONE, z);
        assert_eq!(z - z, Complex::ZERO);
        assert_eq!(-z + z, Complex::ZERO);
    }

    #[test]
    fn i_squared_is_minus_one() {
        assert_eq!(Complex::I * Complex::I, Complex::new(-1.0, 0.0));
    }

    #[test]
    fn abs_and_norm() {
        let z = Complex::new(3.0, 4.0);
        assert_eq!(z.norm_sqr(), 25.0);
        assert_eq!(z.abs(), 5.0);
    }

    #[test]
    fn conj_mul_is_norm() {
        let z = Complex::new(2.0, -7.0);
        let p = z * z.conj();
        assert!((p.re - z.norm_sqr()).abs() < 1e-12);
        assert!(p.im.abs() < 1e-12);
    }

    #[test]
    fn polar_roundtrip() {
        let z = Complex::from_polar(2.0, 0.7);
        assert!((z.abs() - 2.0).abs() < 1e-12);
        assert!((z.arg() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn inverse() {
        let z = Complex::new(1.5, -2.5);
        let p = z * z.inv();
        assert!((p.re - 1.0).abs() < 1e-12);
        assert!(p.im.abs() < 1e-12);
    }

    #[test]
    fn mul_matches_expansion() {
        let a = Complex::new(1.0, 2.0);
        let b = Complex::new(-3.0, 0.5);
        let c = a * b;
        assert!((c.re - (1.0 * -3.0 - 2.0 * 0.5)).abs() < 1e-15);
        assert!((c.im - (1.0 * 0.5 + 2.0 * -3.0)).abs() < 1e-15);
    }

    #[test]
    fn assign_ops() {
        let mut z = Complex::new(1.0, 1.0);
        z += Complex::new(2.0, -1.0);
        assert_eq!(z, Complex::new(3.0, 0.0));
        z -= Complex::new(1.0, 0.0);
        assert_eq!(z, Complex::new(2.0, 0.0));
        z *= Complex::I;
        assert_eq!(z, Complex::new(0.0, 2.0));
    }
}
