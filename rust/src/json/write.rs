//! JSON writer: compact and pretty forms.

use super::Value;
use std::fmt::Write as _;

/// Serialize compactly (no added whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, None, 0);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, Some(2), 0);
    out
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(out, *n),
        Value::String(s) => write_string(out, s),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_value(out, item, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, item, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like most writers in lenient mode.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Shortest roundtrip representation f64 Display provides.
        let _ = write!(out, "{n}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn compact_roundtrip() {
        let src = r#"{"a":[1,2.5,null,true],"b":{"c":"x\ny"},"z":-0.125}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_have_no_decimal() {
        assert_eq!(to_string(&Value::Number(42.0)), "42");
        assert_eq!(to_string(&Value::Number(-1.0)), "-1");
        assert_eq!(to_string(&Value::Number(2.5)), "2.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Number(f64::INFINITY)), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let v = Value::String("a\u{0001}b".into());
        assert_eq!(to_string(&v), "\"a\\u0001b\"");
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn pretty_is_parseable_and_indented() {
        let v = Value::object(vec![
            ("arr", Value::from(vec![1i64, 2])),
            ("obj", Value::object(vec![("k", Value::from("v"))])),
        ]);
        let s = to_string_pretty(&v);
        assert!(s.contains("\n  \"arr\": [\n    1,"), "got: {s}");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(to_string(&Value::Array(vec![])), "[]");
        assert_eq!(to_string(&Value::Object(Default::default())), "{}");
        assert_eq!(to_string_pretty(&Value::Array(vec![])), "[]");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::String("π😀".into());
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    use crate::rng::{Pcg32, UniformRng};

    #[test]
    fn fuzzish_roundtrip() {
        // generate a few structured values and round-trip them
        let mut rng = Pcg32::seeded(1234);
        for _ in 0..50 {
            let v = random_value(&mut rng, 0);
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "failed on {s}");
            let p = to_string_pretty(&v);
            assert_eq!(parse(&p).unwrap(), v, "failed on pretty {p}");
        }
    }

    fn random_value(rng: &mut crate::rng::Pcg32, depth: usize) -> Value {
        let pick = if depth > 3 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Number((rng.next_u32() as f64 / 1e4).round() / 1e2),
            3 => Value::String(format!("s{}", rng.below(1000))),
            4 => Value::Array((0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Value::Object(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
}
