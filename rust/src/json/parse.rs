//! Recursive-descent JSON parser with positioned errors.

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with 1-based line/column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Human-readable description.
    pub message: String,
    /// 1-based line.
    pub line: usize,
    /// 1-based column.
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (a single value with optional surrounding
/// whitespace).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let mut line = 1;
        let mut col = 1;
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError {
            message: msg.to_string(),
            line,
            col,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => {
                    self.pos += 1;
                }
                // Extension: // line comments, since WCT configs commonly
                // pass through Jsonnet which allows them.
                b'/' if self.bytes.get(self.pos + 1) == Some(&b'/') => {
                    while let Some(b) = self.peek() {
                        self.pos += 1;
                        if b == b'\n' {
                            break;
                        }
                    }
                }
                _ => break,
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => {
                self.depth += 1;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.depth += 1;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("invalid utf-8"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // frac
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected after decimal point"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // exp
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.25e2").unwrap(), Value::Number(-325.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn containers() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.path("a.2.b"), Some(&Value::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn whitespace_and_comments() {
        let v = parse("  {\n // comment\n \"a\" : 1 // trailing\n}\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"π ≈ 3.14159\"").unwrap();
        assert_eq!(v.as_str(), Some("π ≈ 3.14159"));
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n\"a\": }").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 6, "col={}", e.col);
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "tru", "01", "1.", "1e", "\"unterminated", "{\"a\" 1}", "[1 2]",
            "nullx", "{\"a\":1} extra", "\"\\q\"", "\"\\ud800\"",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_is_bounded() {
        let doc = "[".repeat(300) + &"]".repeat(300);
        assert!(parse(&doc).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = parse(r#"{"a":1,"a":2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn numbers_roundtrip_precision() {
        let v = parse("0.1").unwrap();
        assert_eq!(v.as_f64(), Some(0.1));
        let v = parse("1e-9").unwrap();
        assert_eq!(v.as_f64(), Some(1e-9));
    }
}
