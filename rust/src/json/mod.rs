//! JSON substrate (parser + writer), from scratch.
//!
//! Wire-Cell Toolkit is configured through JSON/Jsonnet documents and can
//! exchange depo sets as JSON; serde is not available in the vendored
//! registry, so this module provides the value model, a recursive-descent
//! parser with line/column errors, and a writer (compact and pretty).

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// A JSON value. Objects use `BTreeMap` for deterministic ordering,
/// which keeps config hashing and golden-file tests stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64, like most dynamic JSON models).
    Number(f64),
    /// String.
    String(String),
    /// Array.
    Array(Vec<Value>),
    /// Object.
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Access as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Access as number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Access as integer (number with no fractional part).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Number(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    /// Access as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// Access as string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// Access as array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Access as object map.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Lookup by dotted path, e.g. `"detector.planes.0.pitch"`.
    pub fn path(&self, dotted: &str) -> Option<&Value> {
        let mut cur = self;
        for seg in dotted.split('.') {
            cur = match cur {
                Value::Object(o) => o.get(seg)?,
                Value::Array(a) => a.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Deep-merge `other` into `self`: objects merge recursively, any
    /// other kind is replaced.  This is the config-overlay operation
    /// (defaults ⊕ file ⊕ command line).
    pub fn merge(&mut self, other: &Value) {
        match (self, other) {
            (Value::Object(dst), Value::Object(src)) => {
                for (k, v) in src {
                    match dst.get_mut(k) {
                        Some(slot) => slot.merge(v),
                        None => {
                            dst.insert(k.clone(), v.clone());
                        }
                    }
                }
            }
            (dst, src) => *dst = src.clone(),
        }
    }

    /// Build an object from pairs (test/config convenience).
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Number(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Number(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Number(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::String(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::String(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = Value::object(vec![
            ("a", Value::from(1.5)),
            ("b", Value::from(true)),
            ("c", Value::from("hi")),
            ("d", Value::from(vec![1i64, 2, 3])),
        ]);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("d").unwrap().as_array().unwrap().len(), 3);
        assert!(v.get("zz").is_none());
    }

    #[test]
    fn as_i64_rejects_fractions() {
        assert_eq!(Value::Number(3.0).as_i64(), Some(3));
        assert_eq!(Value::Number(3.5).as_i64(), None);
        assert_eq!(Value::Number(-2.0).as_i64(), Some(-2));
        assert_eq!(Value::Number(-2.0).as_usize(), None);
    }

    #[test]
    fn path_lookup() {
        let v = Value::object(vec![(
            "detector",
            Value::object(vec![(
                "planes",
                Value::Array(vec![Value::object(vec![("pitch", Value::from(3.0))])]),
            )]),
        )]);
        assert_eq!(v.path("detector.planes.0.pitch").unwrap().as_f64(), Some(3.0));
        assert!(v.path("detector.planes.1.pitch").is_none());
        assert!(v.path("detector.nope").is_none());
    }

    #[test]
    fn merge_overlays() {
        let mut base = Value::object(vec![
            ("a", Value::from(1i64)),
            ("nest", Value::object(vec![("x", Value::from(1i64)), ("y", Value::from(2i64))])),
        ]);
        let over = Value::object(vec![
            ("nest", Value::object(vec![("y", Value::from(99i64))])),
            ("b", Value::from("new")),
        ]);
        base.merge(&over);
        assert_eq!(base.path("nest.y").unwrap().as_i64(), Some(99));
        assert_eq!(base.path("nest.x").unwrap().as_i64(), Some(1));
        assert_eq!(base.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(base.get("b").unwrap().as_str(), Some("new"));
    }

    #[test]
    fn merge_replaces_non_objects() {
        let mut base = Value::from(vec![1i64, 2]);
        base.merge(&Value::from(7i64));
        assert_eq!(base.as_i64(), Some(7));
    }
}
