//! Scatter-add: accumulate patches onto the plane grid.
//!
//! The second sub-step of S(t,x) construction (§2.1.1: "add up all the
//! patches to a large grid (~10k×10k)") and the subject of the paper's
//! Figure 5, which benchmarks `Kokkos::atomic_add` scaling for this
//! operation.  Three implementations:
//!
//! * [`scatter_serial`] — the reference, one thread, no atomics.
//! * [`scatter_atomic`] — `parallel_for` over patches with CAS-loop
//!   float atomic adds into the shared grid (the Figure-5 subject).
//! * [`scatter_tiled`] — per-thread private accumulation over disjoint
//!   *time* stripes with a final reduction; the atomics-free ablation.
//!
//! All fold the fine (oversampled) patch bins onto the coarse
//! (wire, tick) grid via [`GridSpec::wire_of`] / [`GridSpec::tick_of`].

use crate::parallel::{as_atomic_f32, parallel_for, ExecPolicy, SendPtr, ThreadPool};
use crate::raster::{GridSpec, Patch};

/// The coarse accumulation grid of one plane: row-major
/// `[nwires][nticks]` f32.
#[derive(Clone, Debug)]
pub struct PlaneGrid {
    /// Wires (rows).
    pub nwires: usize,
    /// Ticks (columns).
    pub nticks: usize,
    /// Row-major charge values (electrons).
    pub data: Vec<f32>,
}

impl PlaneGrid {
    /// Zeroed grid for a spec's coarse shape.
    pub fn for_spec(spec: &GridSpec) -> Self {
        let (nwires, nticks) = spec.coarse_shape();
        Self {
            nwires,
            nticks,
            data: vec![0.0; nwires * nticks],
        }
    }

    /// Total charge on the grid.
    pub fn total(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum()
    }

    /// Value at (wire, tick).
    pub fn at(&self, w: usize, t: usize) -> f32 {
        self.data[w * self.nticks + t]
    }

    /// Zero all bins (for benchmark repetitions).
    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }

    /// FNV-1a digest over the grid's exact bit content (shape plus
    /// every bin's `f32` bit pattern).
    ///
    /// This is the bit-parity witness the fused kernel
    /// (`crate::kernel`) and `wire-cell rasterize` use: two raster
    /// paths that claim to compute the same physics must produce equal
    /// digests, one-ulp differences included.
    pub fn digest(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        h = (h ^ self.nwires as u64).wrapping_mul(PRIME);
        h = (h ^ self.nticks as u64).wrapping_mul(PRIME);
        for &v in &self.data {
            h = (h ^ u64::from(v.to_bits())).wrapping_mul(PRIME);
        }
        h
    }
}

/// Serial scatter-add of patches onto the grid.
pub fn scatter_serial(grid: &mut PlaneGrid, spec: &GridSpec, patches: &[Patch]) {
    for patch in patches {
        scatter_one(grid.nticks, &mut grid.data, spec, patch);
    }
}

fn scatter_one(nticks: usize, data: &mut [f32], spec: &GridSpec, patch: &Patch) {
    for p in 0..patch.np {
        let Some(w) = spec.wire_of(patch.pbin0 + p as i64) else {
            continue;
        };
        let row = &mut data[w * nticks..(w + 1) * nticks];
        for t in 0..patch.nt {
            let Some(k) = spec.tick_of(patch.tbin0 + t as i64) else {
                continue;
            };
            row[k] += patch.values[p * patch.nt + t];
        }
    }
}

/// Parallel scatter-add using float atomics — `Kokkos::atomic_add`
/// analog (Figure 5).  Patches are distributed over pool workers; every
/// bin update is a CAS-loop atomic add into the shared grid.
pub fn scatter_atomic(
    grid: &mut PlaneGrid,
    spec: &GridSpec,
    patches: &[Patch],
    pool: &ThreadPool,
    policy: ExecPolicy,
) {
    let nticks = grid.nticks;
    let atoms = as_atomic_f32(&mut grid.data);
    parallel_for(pool, policy, patches.len(), 8, |range| {
        for patch in &patches[range] {
            for p in 0..patch.np {
                let Some(w) = spec.wire_of(patch.pbin0 + p as i64) else {
                    continue;
                };
                for t in 0..patch.nt {
                    let Some(k) = spec.tick_of(patch.tbin0 + t as i64) else {
                        continue;
                    };
                    atoms[w * nticks + k].fetch_add(patch.values[p * patch.nt + t]);
                }
            }
        }
    });
}

/// Atomics-free parallel scatter: workers own disjoint *tick stripes*
/// of the grid; every worker scans all patches but only writes bins in
/// its stripe.  Trades redundant patch scans for zero contention — the
/// ablation point DESIGN.md §6 calls out.
pub fn scatter_tiled(
    grid: &mut PlaneGrid,
    spec: &GridSpec,
    patches: &[Patch],
    pool: &ThreadPool,
    policy: ExecPolicy,
) {
    let nstripes = policy.concurrency();
    if nstripes <= 1 {
        scatter_serial(grid, spec, patches);
        return;
    }
    let nticks = grid.nticks;
    let nwires = grid.nwires;
    let stripe = nticks.div_ceil(nstripes);
    let ptr = SendPtr(grid.data.as_mut_ptr());
    parallel_for(pool, policy, nstripes, 1, |range| {
        for s in range {
            let t_lo = s * stripe;
            let t_hi = ((s + 1) * stripe).min(nticks);
            if t_lo >= t_hi {
                continue;
            }
            // SAFETY: each stripe worker writes only bins whose tick
            // index lies in its disjoint [t_lo, t_hi) range, so no two
            // workers touch the same element.
            let data = unsafe { std::slice::from_raw_parts_mut(ptr.get(), nwires * nticks) };
            for patch in patches {
                for t in 0..patch.nt {
                    let Some(k) = spec.tick_of(patch.tbin0 + t as i64) else {
                        continue;
                    };
                    if k < t_lo || k >= t_hi {
                        continue;
                    }
                    for p in 0..patch.np {
                        let Some(w) = spec.wire_of(patch.pbin0 + p as i64) else {
                            continue;
                        };
                        data[w * nticks + k] += patch.values[p * patch.nt + t];
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::*;

    fn spec() -> GridSpec {
        GridSpec::new(20, 3.0 * MM, 32, 0.5 * US, 4, 2)
    }

    fn patch(pbin0: i64, tbin0: i64, np: usize, nt: usize, val: f32) -> Patch {
        Patch {
            pbin0,
            tbin0,
            np,
            nt,
            values: vec![val; np * nt],
        }
    }

    #[test]
    fn serial_folds_fine_bins() {
        let s = spec();
        let mut g = PlaneGrid::for_spec(&s);
        // one patch covering exactly wire 0's 4 fine bins x tick 0's 2
        let p = patch(0, 0, 4, 2, 1.0);
        scatter_serial(&mut g, &s, &[p]);
        assert_eq!(g.at(0, 0), 8.0);
        assert_eq!(g.total(), 8.0);
    }

    #[test]
    fn serial_clips_negative_bins() {
        let s = spec();
        let mut g = PlaneGrid::for_spec(&s);
        let p = patch(-2, -1, 4, 3, 1.0);
        scatter_serial(&mut g, &s, &[p]);
        // only fine bins >= 0 accumulate: 2 pitch x 2 time
        assert_eq!(g.total(), 4.0);
        assert_eq!(g.at(0, 0), 4.0);
    }

    #[test]
    fn serial_clips_past_end() {
        let s = spec();
        let (fp, ft) = s.fine_shape();
        let mut g = PlaneGrid::for_spec(&s);
        let p = patch(fp as i64 - 2, ft as i64 - 1, 4, 3, 1.0);
        scatter_serial(&mut g, &s, &[p]);
        assert_eq!(g.total(), 2.0);
    }

    #[test]
    fn atomic_matches_serial() {
        let s = spec();
        let pool = ThreadPool::new(4);
        let patches: Vec<Patch> = (0..200)
            .map(|i| patch((i % 70) as i64, (i % 50) as i64, 5, 7, 0.5 + (i % 3) as f32))
            .collect();
        let mut serial = PlaneGrid::for_spec(&s);
        scatter_serial(&mut serial, &s, &patches);
        let mut atomic = PlaneGrid::for_spec(&s);
        scatter_atomic(&mut atomic, &s, &patches, &pool, ExecPolicy::Threads(4));
        for (a, b) in serial.data.iter().zip(&atomic.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn tiled_matches_serial() {
        let s = spec();
        let pool = ThreadPool::new(4);
        let patches: Vec<Patch> = (0..100)
            .map(|i| patch((i % 60) as i64, (i % 40) as i64, 6, 5, 1.0))
            .collect();
        let mut serial = PlaneGrid::for_spec(&s);
        scatter_serial(&mut serial, &s, &patches);
        let mut tiled = PlaneGrid::for_spec(&s);
        scatter_tiled(&mut tiled, &s, &patches, &pool, ExecPolicy::Threads(4));
        for (a, b) in serial.data.iter().zip(&tiled.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn tiled_serial_policy_falls_back() {
        let s = spec();
        let pool = ThreadPool::new(2);
        let patches = vec![patch(0, 0, 4, 2, 1.0)];
        let mut g = PlaneGrid::for_spec(&s);
        scatter_tiled(&mut g, &s, &patches, &pool, ExecPolicy::Serial);
        assert_eq!(g.total(), 8.0);
    }

    #[test]
    fn charge_conserved_for_in_bounds_patches() {
        let s = spec();
        let patches: Vec<Patch> = (0..50)
            .map(|i| patch(4 + (i % 50) as i64, 2 + (i % 30) as i64, 4, 6, 2.0))
            .collect();
        let expect: f64 = patches.iter().map(|p| p.total()).sum();
        let mut g = PlaneGrid::for_spec(&s);
        scatter_serial(&mut g, &s, &patches);
        assert!((g.total() - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn digest_is_bit_sensitive() {
        let s = spec();
        let mut a = PlaneGrid::for_spec(&s);
        let mut b = PlaneGrid::for_spec(&s);
        scatter_serial(&mut a, &s, &[patch(0, 0, 2, 2, 1.0)]);
        scatter_serial(&mut b, &s, &[patch(0, 0, 2, 2, 1.0)]);
        assert_eq!(a.digest(), b.digest());
        // a one-ulp change must flip the digest
        b.data[0] = f32::from_bits(b.data[0].to_bits() + 1);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn clear_resets() {
        let s = spec();
        let mut g = PlaneGrid::for_spec(&s);
        scatter_serial(&mut g, &s, &[patch(0, 0, 2, 2, 1.0)]);
        assert!(g.total() > 0.0);
        g.clear();
        assert_eq!(g.total(), 0.0);
    }

    #[test]
    fn property_scatter_equivalence() {
        crate::testing::forall("atomic == serial scatter", 20, |g| {
            let s = spec();
            let pool = ThreadPool::new(3);
            let n = g.usize_in(1..60);
            let patches: Vec<Patch> = (0..n)
                .map(|i| {
                    let np = 1 + (i % 7);
                    let nt = 1 + (i % 9);
                    Patch {
                        pbin0: (i % 90) as i64 - 5,
                        tbin0: (i % 70) as i64 - 3,
                        np,
                        nt,
                        values: (0..np * nt).map(|k| (k % 5) as f32 * 0.25).collect(),
                    }
                })
                .collect();
            let mut a = PlaneGrid::for_spec(&s);
            scatter_serial(&mut a, &s, &patches);
            let mut b = PlaneGrid::for_spec(&s);
            scatter_atomic(&mut b, &s, &patches, &pool, ExecPolicy::Threads(3));
            let close = a
                .data
                .iter()
                .zip(&b.data)
                .all(|(x, y)| (x - y).abs() < 1e-3);
            g.assert(close, "grids differ");
        });
    }
}
