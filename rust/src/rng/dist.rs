//! Distributions: normal (Box–Muller) and binomial.
//!
//! The binomial sampler is the heart of the paper's "fluctuation" step:
//! each rasterized bin carrying a mean of `n·p` electrons receives a
//! binomially fluctuated integer count.  `std::binomial_distribution` in
//! the ref-CPU implementation is expensive enough to dominate the whole
//! rasterization (Table 2); we reproduce that cost profile with an exact
//! sampler, and the pool/approx variants used by the ported code paths.

use super::UniformRng;

/// One normal variate via Box–Muller (the transform the paper used to
/// fill Kokkos' missing normal RNG, §4.3.1).  Computes two, discards one;
/// use [`BoxMuller`] to keep both.
pub fn normal<R: UniformRng>(rng: &mut R, mean: f64, sigma: f64) -> f64 {
    let u1 = rng.uniform_pos();
    let u2 = rng.uniform();
    let r = (-2.0 * u1.ln()).sqrt();
    mean + sigma * r * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Box–Muller generator that caches the second variate of each pair.
#[derive(Clone, Debug, Default)]
pub struct BoxMuller {
    cached: Option<f64>,
}

impl BoxMuller {
    /// New generator with an empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Next standard-normal variate.
    pub fn sample<R: UniformRng>(&mut self, rng: &mut R) -> f64 {
        if let Some(z) = self.cached.take() {
            return z;
        }
        let u1 = rng.uniform_pos();
        let u2 = rng.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached = Some(r * s);
        r * c
    }
}

/// Exact binomial(n, p) sampler by CDF inversion.
///
/// Cost is O(n·p) per draw on average — *intentionally* similar to the
/// per-draw cost anatomy of `std::binomial_distribution` for the small
/// n (tens to thousands of electrons per bin) seen by the fluctuation
/// step.  This is the "ref-CPU" code path.
pub fn binomial_exact<R: UniformRng>(rng: &mut R, n: u64, p: f64) -> u64 {
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    // Work with p <= 0.5 and mirror.
    let (pp, flip) = if p > 0.5 { (1.0 - p, true) } else { (p, false) };
    let q = 1.0 - pp;
    // P(X=0) = q^n computed in log space for stability.  When it
    // underflows (huge n·p) CDF inversion from 0 is numerically dead;
    // fall back to the normal approximation like production binomial
    // samplers (std's BTPE region) do.
    let log_p0 = n as f64 * q.ln();
    if log_p0 < -700.0 {
        let z = normal(rng, 0.0, 1.0);
        return binomial_normal_approx(n, p, z);
    }
    let mut pdf = log_p0.exp();
    let mut cdf = pdf;
    let u = rng.uniform();
    let mut k: u64 = 0;
    // Invert the CDF by walking up the pmf recurrence.
    while u > cdf && k < n {
        k += 1;
        pdf *= (n - k + 1) as f64 / k as f64 * (pp / q);
        cdf += pdf;
        if pdf < 1e-18 && cdf > u {
            break;
        }
    }
    if flip {
        n - k
    } else {
        k
    }
}

/// Normal-approximation binomial: round(N(np, np(1-p))), clamped to
/// [0, n].  This is what the device code paths use (one pre-computed
/// normal variate per bin), matching the paper's pool-based fluctuation.
pub fn binomial_normal_approx(n: u64, p: f64, z: f64) -> u64 {
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    let mean = n as f64 * p;
    let sigma = (n as f64 * p * (1.0 - p)).sqrt();
    let x = (mean + sigma * z).round();
    x.clamp(0.0, n as f64) as u64
}

/// Adaptive binomial: exact inversion when cheap/necessary
/// (n·p or n·(1-p) below ~30), otherwise the normal approximation with an
/// inline Box–Muller draw.  This mirrors how production WCT trades
/// accuracy for speed and gives the ablation a third point.
pub fn binomial<R: UniformRng>(rng: &mut R, n: u64, p: f64) -> u64 {
    let np = n as f64 * p.min(1.0 - p);
    if np < 30.0 {
        binomial_exact(rng, n, p)
    } else {
        let z = normal(rng, 0.0, 1.0);
        binomial_normal_approx(n, p, z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn moments(vals: &[f64]) -> (f64, f64) {
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(10);
        let vals: Vec<f64> = (0..200_000).map(|_| normal(&mut rng, 3.0, 2.0)).collect();
        let (mean, var) = moments(&vals);
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
        assert!((var - 4.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn box_muller_pairs_match_moments() {
        let mut rng = Pcg32::seeded(11);
        let mut bm = BoxMuller::new();
        let vals: Vec<f64> = (0..200_000).map(|_| bm.sample(&mut rng)).collect();
        let (mean, var) = moments(&vals);
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn box_muller_uses_cached_second() {
        // Two samples should consume exactly 2 uniforms (one pair).
        struct Counting(Pcg32, usize);
        impl crate::rng::UniformRng for Counting {
            fn next_u32(&mut self) -> u32 {
                self.1 += 1;
                self.0.next_u32()
            }
        }
        let mut rng = Counting(Pcg32::seeded(1), 0);
        let mut bm = BoxMuller::new();
        let _ = bm.sample(&mut rng);
        let _ = bm.sample(&mut rng);
        // uniform() consumes 2 u32 per f64 -> 2 uniforms = 4 u32
        assert_eq!(rng.1, 4);
    }

    #[test]
    fn binomial_exact_edge_cases() {
        let mut rng = Pcg32::seeded(12);
        assert_eq!(binomial_exact(&mut rng, 0, 0.5), 0);
        assert_eq!(binomial_exact(&mut rng, 100, 0.0), 0);
        assert_eq!(binomial_exact(&mut rng, 100, 1.0), 100);
        for _ in 0..100 {
            let k = binomial_exact(&mut rng, 10, 0.3);
            assert!(k <= 10);
        }
    }

    #[test]
    fn binomial_exact_moments() {
        let mut rng = Pcg32::seeded(13);
        let (n, p) = (50u64, 0.3);
        let vals: Vec<f64> = (0..100_000)
            .map(|_| binomial_exact(&mut rng, n, p) as f64)
            .collect();
        let (mean, var) = moments(&vals);
        assert!((mean - 15.0).abs() < 0.1, "mean={mean}");
        assert!((var - 10.5).abs() < 0.2, "var={var}");
    }

    #[test]
    fn binomial_exact_mirrored_p() {
        let mut rng = Pcg32::seeded(14);
        let vals: Vec<f64> = (0..100_000)
            .map(|_| binomial_exact(&mut rng, 40, 0.8) as f64)
            .collect();
        let (mean, var) = moments(&vals);
        assert!((mean - 32.0).abs() < 0.1, "mean={mean}");
        assert!((var - 6.4).abs() < 0.2, "var={var}");
    }

    #[test]
    fn binomial_normal_approx_moments() {
        let mut rng = Pcg32::seeded(15);
        let (n, p) = (10_000u64, 0.5);
        let vals: Vec<f64> = (0..50_000)
            .map(|_| binomial_normal_approx(n, p, normal(&mut rng, 0.0, 1.0)) as f64)
            .collect();
        let (mean, var) = moments(&vals);
        assert!((mean - 5000.0).abs() < 2.0, "mean={mean}");
        assert!((var - 2500.0).abs() < 50.0, "var={var}");
    }

    #[test]
    fn binomial_adaptive_matches_exact_regime_moments() {
        let mut rng = Pcg32::seeded(16);
        let vals: Vec<f64> = (0..100_000).map(|_| binomial(&mut rng, 20, 0.4) as f64).collect();
        let (mean, var) = moments(&vals);
        assert!((mean - 8.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.8).abs() < 0.12, "var={var}");
    }

    #[test]
    fn binomial_adaptive_large_n_uses_approx_and_stays_bounded() {
        let mut rng = Pcg32::seeded(17);
        for _ in 0..1000 {
            let k = binomial(&mut rng, 1_000_000, 0.999);
            assert!(k <= 1_000_000);
            assert!(k > 990_000);
        }
    }

    #[test]
    fn binomial_approx_clamps() {
        assert_eq!(binomial_normal_approx(10, 0.5, 100.0), 10);
        assert_eq!(binomial_normal_approx(10, 0.5, -100.0), 0);
        assert_eq!(binomial_normal_approx(0, 0.5, 1.0), 0);
    }
}
