//! Pre-computed random-number pools.
//!
//! The paper's ref-CUDA and Kokkos implementations "factored the RNG out
//! of the fluctuation calculation" into a pool computed once up front
//! (§3, §4.3.1), with concurrent access from many threads.  That single
//! change is responsible for most of the apparent CUDA speedup in
//! Table 2.  [`RandomPool`] reproduces it: a block of pre-drawn variates
//! plus an atomic cursor so workers can grab disjoint slices.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::{normal, Pcg64, UniformRng};

/// A shared pool of pre-computed random variates.
///
/// Filled once (uniforms and standard normals), then handed out in
/// contiguous slices via an atomic cursor.  Wrap-around is deliberate and
/// documented: statistically this re-uses variates after `len` draws,
/// which matches the paper's pool semantics (and is flagged in
/// DESIGN.md as an accepted approximation for benchmarking).
pub struct RandomPool {
    // variate data is behind Arcs so sibling pools (see [`fork`]) can
    // share the bytes while owning private cursors
    uniforms: Arc<Vec<f32>>,
    normals: Arc<Vec<f32>>,
    cursor: AtomicUsize,
}

impl RandomPool {
    /// Generate a pool of `len` uniforms and `len` standard normals from
    /// the given seed.  This is the "RNG factored out" pre-pass whose
    /// cost the paper excludes from the device timings; callers time it
    /// separately (see `bench table2`).
    pub fn generate(seed: u64, len: usize) -> Self {
        assert!(len > 0, "pool length must be positive");
        let mut rng = Pcg64::seeded(seed);
        let mut uniforms = Vec::with_capacity(len);
        let mut normals = Vec::with_capacity(len);
        for _ in 0..len {
            uniforms.push(rng.uniform() as f32);
        }
        for _ in 0..len {
            normals.push(normal(&mut rng, 0.0, 1.0) as f32);
        }
        Self {
            uniforms: Arc::new(uniforms),
            normals: Arc::new(normals),
            cursor: AtomicUsize::new(0),
        }
    }

    /// A sibling pool sharing this pool's (immutable) variate data but
    /// owning a fresh cursor at zero.
    ///
    /// The throughput engine hands one fork per worker: generating the
    /// pool once instead of `workers` times removes the O(workers)
    /// startup cost, while the private cursors let each worker rewind
    /// per event without disturbing the others.
    pub fn fork(&self) -> Self {
        Self {
            uniforms: self.uniforms.clone(),
            normals: self.normals.clone(),
            cursor: AtomicUsize::new(0),
        }
    }

    /// Pool length.
    pub fn len(&self) -> usize {
        self.uniforms.len()
    }

    /// True if the pool is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.uniforms.is_empty()
    }

    /// Reset the shared cursor (between benchmark repetitions so every
    /// run consumes the identical variate sequence).
    pub fn reset(&self) {
        self.cursor.store(0, Ordering::Relaxed);
    }

    /// Atomically claim a block of `count` variates and return the
    /// starting index (modulo the pool length).
    ///
    /// The fused kernel (`crate::kernel`) uses this for *deterministic*
    /// concurrent fluctuation: one block is claimed per event up front,
    /// and every depo reads [`normal_at`](Self::normal_at)`(start +
    /// flat_bin_offset)` — so which thread processes a depo never
    /// changes which variates it consumes.  Reading `(start + j) %
    /// len()` reproduces exactly the sequence [`Self::fill_normals`]
    /// would copy for the same cursor state, which is what makes the
    /// fused path bit-identical to the per-patch one.
    pub fn claim_start(&self, count: usize) -> usize {
        self.cursor.fetch_add(count, Ordering::Relaxed) % self.len()
    }

    /// Atomically claim a cursor for `count` variates.  Thread-safe; the
    /// returned [`PoolCursor`] indexes with wrap-around.
    pub fn claim(&self, count: usize) -> PoolCursor {
        let start = self.cursor.fetch_add(count, Ordering::Relaxed);
        PoolCursor {
            start: start % self.len(),
            len: self.len(),
            offset: 0,
        }
    }

    /// Normal variate at absolute index (wrapping).
    #[inline]
    pub fn normal_at(&self, idx: usize) -> f32 {
        self.normals[idx % self.normals.len()]
    }

    /// Uniform variate at absolute index (wrapping).
    #[inline]
    pub fn uniform_at(&self, idx: usize) -> f32 {
        self.uniforms[idx % self.uniforms.len()]
    }

    /// Raw normal slice (for bulk device upload in the PJRT backend).
    pub fn normals(&self) -> &[f32] {
        &self.normals
    }

    /// Bulk-fill `out` with the next `out.len()` normals (claims one
    /// cursor, copies with at most two memcpys for the wrap) — the
    /// fast path for device-batch staging, ~20× cheaper than
    /// per-element cursor reads.
    pub fn fill_normals(&self, out: &mut [f32]) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let start = self.cursor.fetch_add(n, Ordering::Relaxed) % self.len();
        let first = (self.len() - start).min(n);
        out[..first].copy_from_slice(&self.normals[start..start + first]);
        let mut filled = first;
        while filled < n {
            let take = (n - filled).min(self.len());
            out[filled..filled + take].copy_from_slice(&self.normals[..take]);
            filled += take;
        }
    }

    /// Raw uniform slice.
    pub fn uniforms(&self) -> &[f32] {
        &self.uniforms
    }

    /// Convenience shared handle.
    pub fn shared(seed: u64, len: usize) -> Arc<Self> {
        Arc::new(Self::generate(seed, len))
    }
}

/// A claimed region of the pool; sequential reads with wrap-around.
pub struct PoolCursor {
    start: usize,
    len: usize,
    offset: usize,
}

impl PoolCursor {
    /// Next index into the pool arrays.
    #[inline]
    pub fn next_index(&mut self) -> usize {
        let i = (self.start + self.offset) % self.len;
        self.offset += 1;
        i
    }

    /// Read the next normal from `pool`.
    #[inline]
    pub fn next_normal(&mut self, pool: &RandomPool) -> f32 {
        let i = self.next_index();
        pool.normals[i]
    }

    /// Read the next uniform from `pool`.
    #[inline]
    pub fn next_uniform(&mut self, pool: &RandomPool) -> f32 {
        let i = self.next_index();
        pool.uniforms[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn pool_is_deterministic() {
        let a = RandomPool::generate(42, 1000);
        let b = RandomPool::generate(42, 1000);
        assert_eq!(a.normals(), b.normals());
        assert_eq!(a.uniforms(), b.uniforms());
    }

    #[test]
    fn pool_normals_have_unit_moments() {
        let pool = RandomPool::generate(7, 200_000);
        let n = pool.len() as f64;
        let mean: f64 = pool.normals().iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 = pool.normals().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn claim_hands_out_disjoint_regions() {
        let pool = RandomPool::generate(1, 100);
        let mut c1 = pool.claim(10);
        let mut c2 = pool.claim(10);
        let i1: Vec<usize> = (0..10).map(|_| c1.next_index()).collect();
        let i2: Vec<usize> = (0..10).map(|_| c2.next_index()).collect();
        assert!(i1.iter().all(|i| !i2.contains(i)));
    }

    #[test]
    fn cursor_wraps() {
        let pool = RandomPool::generate(1, 8);
        let mut c = pool.claim(20);
        let idx: Vec<usize> = (0..20).map(|_| c.next_index()).collect();
        assert!(idx.iter().all(|&i| i < 8));
        // The sequence must visit every slot at least twice over 20 draws of 8.
        for slot in 0..8 {
            assert!(idx.iter().filter(|&&i| i == slot).count() >= 2);
        }
    }

    #[test]
    fn concurrent_claims_do_not_overlap() {
        let pool = RandomPool::shared(3, 10_000);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = pool.clone();
            handles.push(thread::spawn(move || {
                let mut c = p.claim(100);
                (0..100).map(|_| c.next_index()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        // 800 < 10_000 so no wrap: all indices must be unique.
        assert_eq!(all.len(), before);
    }

    #[test]
    fn reset_restarts_sequence() {
        let pool = RandomPool::generate(5, 64);
        let mut c1 = pool.claim(4);
        let seq1: Vec<f32> = (0..4).map(|_| c1.next_normal(&pool)).collect();
        pool.reset();
        let mut c2 = pool.claim(4);
        let seq2: Vec<f32> = (0..4).map(|_| c2.next_normal(&pool)).collect();
        assert_eq!(seq1, seq2);
    }

    #[test]
    #[should_panic]
    fn zero_length_pool_panics() {
        let _ = RandomPool::generate(1, 0);
    }

    #[test]
    fn claim_start_matches_fill_normals_sequence() {
        // the fused kernel's block indexing must reproduce exactly what
        // per-patch fill_normals calls would have read
        let a = RandomPool::generate(21, 64);
        let b = RandomPool::generate(21, 64);
        // advance both cursors identically first
        let mut burn = vec![0.0f32; 10];
        a.fill_normals(&mut burn);
        b.fill_normals(&mut burn);
        // per-patch side: two consecutive fills of 7 and 5
        let mut fa = vec![0.0f32; 7];
        let mut fb = vec![0.0f32; 5];
        a.fill_normals(&mut fa);
        a.fill_normals(&mut fb);
        // fused side: one block claim of 12, indexed flat
        let start = b.claim_start(12);
        assert_eq!(start, 10);
        let flat: Vec<f32> = (0..12).map(|j| b.normal_at(start + j)).collect();
        assert_eq!(&flat[..7], &fa[..]);
        assert_eq!(&flat[7..], &fb[..]);
        // wrap-around: claim past the end still matches fill_normals
        let start = b.claim_start(60); // 22 + 60 > 64 → wraps
        let flat: Vec<f32> = (0..60).map(|j| b.normal_at(start + j)).collect();
        let mut filled = vec![0.0f32; 60];
        a.fill_normals(&mut filled);
        assert_eq!(flat, filled);
    }

    #[test]
    fn fork_shares_data_with_private_cursor() {
        let a = RandomPool::generate(9, 64);
        let mut ca = a.claim(8);
        let _burn: Vec<usize> = (0..8).map(|_| ca.next_index()).collect();
        let b = a.fork();
        assert_eq!(a.normals(), b.normals()); // same bytes, not a regen
        // b's cursor starts fresh even though a's has advanced
        let mut cb = b.claim(4);
        assert_eq!(cb.next_index(), 0);
        // and advancing b leaves a's cursor untouched
        let mut ca2 = a.claim(1);
        assert_eq!(ca2.next_index(), 8);
    }
}
