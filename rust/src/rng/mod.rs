//! Random-number substrate.
//!
//! The paper's central Table-2 finding is that the serial CPU
//! implementation spends ~95% of the rasterization time inside
//! `std::binomial_distribution` (the per-bin charge "fluctuation"), and
//! that factoring the RNG *out* of the hot loop into a pre-computed pool
//! recovers a ~20× speedup (ref-CPU 3.57 s → ref-CPU-noRNG 0.18 s).
//!
//! This module provides everything needed to reproduce both sides of that
//! comparison:
//!
//! * [`Pcg32`] — a small, fast, seedable PCG-XSH-RR generator (the
//!   workhorse; equivalent role to `std::mt19937` in the original).
//! * [`normal`] / [`BoxMuller`] — Box–Muller normal variates, the same
//!   transform the paper used to work around Kokkos' missing normal RNG
//!   (§4.3.1).
//! * [`binomial`] — an *exact* inverted-CDF binomial sampler for small n
//!   and a normal-approximation fallback for large n, mirroring the cost
//!   profile of `std::binomial_distribution`.
//! * [`RandomPool`] — the pre-computed random-number pool used by the
//!   ref-CUDA and Kokkos implementations (§3, §4.3.1) with concurrent
//!   block hand-out.

mod pcg;
mod dist;
mod pool;

pub use pcg::{Pcg32, Pcg64, SplitMix64};
pub use dist::{binomial, binomial_exact, binomial_normal_approx, normal, BoxMuller};
pub use pool::{PoolCursor, RandomPool};

/// Trait for a minimal uniform generator so distributions can run over
/// any engine (used by the property tests to swap in counting stubs).
pub trait UniformRng {
    /// Next uniform u32 over the full range.
    fn next_u32(&mut self) -> u32;

    /// Next uniform u64 over the full range.
    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in [0, bound) using Lemire's method.
    fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_pos_never_zero() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..10_000 {
            assert!(rng.uniform_pos() > 0.0);
        }
    }

    #[test]
    fn below_is_unbiased_at_small_bound() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        let expect = n as f64 / 7.0;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 5.0 * expect.sqrt(),
                "count {c} far from {expect}"
            );
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::seeded(4);
        for bound in [1u32, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(rng.below(bound) < bound);
            }
        }
    }
}
