//! PCG family generators (O'Neill 2014) plus SplitMix64 seeding.

use super::UniformRng;

const PCG32_MULT: u64 = 6364136223846793005;
const PCG64_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

/// SplitMix64 — used to expand a single u64 seed into stream state.
/// Also a perfectly serviceable generator in its own right.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next u64.
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

impl UniformRng for SplitMix64 {
    fn next_u32(&mut self) -> u32 {
        (self.next() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next()
    }
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output. Fast, statistically
/// strong, tiny — the default engine everywhere in this crate.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Construct from explicit state/stream (the PCG reference API).
    pub fn new(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        let _ = rng.next_u32();
        rng
    }

    /// Construct from a single seed via SplitMix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::new(sm.next(), sm.next())
    }

    /// Derive an independent stream for worker `i` (stable given the
    /// parent seed) — used by the threaded backends so each thread gets
    /// its own reproducible stream.
    pub fn split(&self, i: u64) -> Self {
        let mut sm = SplitMix64::new(self.state ^ (0xa076_1d64_78bd_642f_u64.wrapping_mul(i + 1)));
        Self::new(sm.next(), sm.next())
    }
}

impl UniformRng for Pcg32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG32_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

/// PCG-XSL-RR 128/64: 128-bit state, 64-bit output. Used where a wider
/// period matters (the big pre-computed pools).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Construct from explicit state/stream.
    pub fn new(initstate: u128, initseq: u128) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        let _ = rng.next_u64();
        rng.state = rng.state.wrapping_add(initstate);
        let _ = rng.next_u64();
        rng
    }

    /// Construct from a single seed via SplitMix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let a = ((sm.next() as u128) << 64) | sm.next() as u128;
        let b = ((sm.next() as u128) << 64) | sm.next() as u128;
        Self::new(a, b)
    }
}

impl UniformRng for Pcg64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG64_MULT).wrapping_add(self.inc);
        let xored = ((old >> 64) as u64) ^ (old as u64);
        let rot = (old >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_reference_values() {
        // First outputs of pcg32 with the reference demo seeding
        // (state=42, seq=54), from the PCG minimal C library.
        let mut rng = Pcg32::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e,
        ];
        for &e in &expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn seeded_is_deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(8);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_are_independent() {
        let root = Pcg32::seeded(1);
        let mut s0 = root.split(0);
        let mut s1 = root.split(1);
        let same = (0..64).filter(|_| s0.next_u32() == s1.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_is_stable() {
        let root = Pcg32::seeded(11);
        let mut a = root.split(3);
        let mut b = root.split(3);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pcg64_runs_and_is_uniformish() {
        let mut rng = Pcg64::seeded(99);
        let mut ones = 0u32;
        let n = 4096;
        for _ in 0..n {
            ones += (rng.next_u64() & 1) as u32;
        }
        // within 5 sigma of n/2
        let sigma = (n as f64 / 4.0).sqrt();
        assert!((ones as f64 - n as f64 / 2.0).abs() < 5.0 * sigma);
    }

    #[test]
    fn splitmix_known_progression() {
        let mut sm = SplitMix64::new(0);
        let a = sm.next();
        let b = sm.next();
        assert_ne!(a, b);
        // stable across runs
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next(), a);
        assert_eq!(sm2.next(), b);
    }

    #[test]
    fn mean_and_variance_of_uniform() {
        let mut rng = Pcg32::seeded(5);
        let n = 100_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let u = rng.uniform();
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }
}
