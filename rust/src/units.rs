//! Wire-Cell style system of units.
//!
//! The Wire-Cell Toolkit (and this reproduction) expresses every physical
//! quantity as a plain `f64` in a coherent unit system, mirroring the
//! CLHEP/Geant4 convention used by the original C++ code base:
//!
//! * length:   millimeter (`MM` = 1.0)
//! * time:     nanosecond (`NS` = 1.0)
//! * energy:   mega-electron-volt (`MEV` = 1.0)
//! * charge:   positron charge (`EPLUS` = 1.0)
//!
//! Every other unit is defined as a multiple of these base units.  A value
//! is *stored* in the base system and *expressed* in a unit by dividing:
//!
//! ```
//! use wirecell::units::*;
//! let drift_speed = 1.6 * MM / US;       // store
//! let in_cm_per_us = drift_speed / (CM / US);   // express
//! assert!((in_cm_per_us - 0.16).abs() < 1e-12);
//! ```

#![allow(clippy::excessive_precision)]

// ---------------------------------------------------------------- length
/// Millimeter — base length unit.
pub const MM: f64 = 1.0;
/// Centimeter.
pub const CM: f64 = 10.0 * MM;
/// Meter.
pub const M: f64 = 1000.0 * MM;
/// Kilometer.
pub const KM: f64 = 1000.0 * M;
/// Micrometer.
pub const UM: f64 = 1e-3 * MM;
/// Nanometer.
pub const NM: f64 = 1e-6 * MM;

// ------------------------------------------------------------------ time
/// Nanosecond — base time unit.
pub const NS: f64 = 1.0;
/// Microsecond.
pub const US: f64 = 1000.0 * NS;
/// Millisecond.
pub const MS: f64 = 1e6 * NS;
/// Second.
pub const S: f64 = 1e9 * NS;

// ---------------------------------------------------------------- energy
/// Mega-electron-volt — base energy unit.
pub const MEV: f64 = 1.0;
/// Electron-volt.
pub const EV: f64 = 1e-6 * MEV;
/// Kilo-electron-volt.
pub const KEV: f64 = 1e-3 * MEV;
/// Giga-electron-volt.
pub const GEV: f64 = 1e3 * MEV;

// ---------------------------------------------------------------- charge
/// Charge of a positron — base charge unit.
pub const EPLUS: f64 = 1.0;
/// Coulomb expressed in positron charges.
pub const COULOMB: f64 = EPLUS / 1.602_176_634e-19;
/// Femtocoulomb — the natural scale of LArTPC wire signals.
pub const FC: f64 = 1e-15 * COULOMB;
/// Picocoulomb.
pub const PC: f64 = 1e-12 * COULOMB;

// --------------------------------------------------------------- voltage
/// Megavolt — coherent with MeV / eplus.
pub const MEGAVOLT: f64 = MEV / EPLUS;
/// Volt.
pub const VOLT: f64 = 1e-6 * MEGAVOLT;
/// Kilovolt.
pub const KILOVOLT: f64 = 1e-3 * MEGAVOLT;
/// Millivolt.
pub const MILLIVOLT: f64 = 1e-3 * VOLT;

// ----------------------------------------------------------------- angle
/// Radian — base angle unit.
pub const RADIAN: f64 = 1.0;
/// Degree.
pub const DEGREE: f64 = std::f64::consts::PI / 180.0 * RADIAN;

// ------------------------------------------------------------- frequency
/// Hertz (cycles per second) in the base system.
pub const HZ: f64 = 1.0 / S;
/// Kilohertz.
pub const KHZ: f64 = 1e3 * HZ;
/// Megahertz.
pub const MHZ: f64 = 1e6 * HZ;

// ------------------------------------------------------- physical consts
/// Physical constants used by the simulation, in the base unit system.
pub mod consts {
    use super::*;

    /// Mean ionization energy to create one electron–ion pair in LAr.
    /// W_i = 23.6 eV per pair.
    pub const W_ION: f64 = 23.6 * EV;

    /// Nominal electron drift speed at 500 V/cm, 87 K: ~1.6 mm/µs.
    pub const DRIFT_SPEED: f64 = 1.6 * MM / US;

    /// Longitudinal diffusion coefficient D_L ≈ 7.2 cm²/s
    /// (MicroBooNE-like value).
    pub const DIFFUSION_L: f64 = 7.2 * CM * CM / S;

    /// Transverse diffusion coefficient D_T ≈ 12.0 cm²/s.
    pub const DIFFUSION_T: f64 = 12.0 * CM * CM / S;

    /// Electron lifetime in purified LAr (optimistic): 8 ms.
    pub const ELECTRON_LIFETIME: f64 = 8.0 * MS;

    /// Liquid argon density, 1.396 g/cm³ — expressed here only through
    /// dE/dx products so we keep it as a plain number with its own tag.
    pub const LAR_DENSITY_G_PER_CM3: f64 = 1.396;

    /// MIP most-probable dE/dx in LAr ≈ 1.7 MeV/cm (restricted), mean 2.1.
    pub const MIP_DEDX_MPV: f64 = 1.7 * MEV / CM;
    /// MIP mean dE/dx.
    pub const MIP_DEDX_MEAN: f64 = 2.1 * MEV / CM;

    /// Nominal LAr electric field for recombination models: 500 V/cm.
    pub const NOMINAL_EFIELD: f64 = 500.0 * VOLT / CM;
}

/// Format a value expressed in `unit` with the given suffix, for reports.
pub fn with_unit(value: f64, unit: f64, suffix: &str) -> String {
    format!("{:.4} {}", value / unit, suffix)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_units_are_unity() {
        assert_eq!(MM, 1.0);
        assert_eq!(NS, 1.0);
        assert_eq!(MEV, 1.0);
        assert_eq!(EPLUS, 1.0);
    }

    #[test]
    fn length_ratios() {
        assert_eq!(CM / MM, 10.0);
        assert_eq!(M / CM, 100.0);
        assert_eq!(KM / M, 1000.0);
        assert!((UM / MM - 1e-3).abs() < 1e-18);
    }

    #[test]
    fn time_ratios() {
        assert_eq!(US / NS, 1000.0);
        assert_eq!(S / MS, 1000.0);
        assert_eq!(MS / US, 1000.0);
    }

    #[test]
    fn energy_ratios() {
        assert!((MEV / EV - 1e6).abs() < 1e-6);
        assert_eq!(GEV / MEV, 1000.0);
    }

    #[test]
    fn charge_conversions() {
        // 1 fC ≈ 6241.5 electrons
        let electrons_per_fc = FC / EPLUS;
        assert!((electrons_per_fc - 6241.509).abs() < 0.1);
    }

    #[test]
    fn drift_speed_expression() {
        let v = consts::DRIFT_SPEED;
        assert!((v / (CM / US) - 0.16).abs() < 1e-12);
        assert!((v / (M / MS) - 1.6).abs() < 1e-12);
    }

    #[test]
    fn w_ion_yield() {
        // A 1 MeV deposit should liberate ~42k electrons.
        let n = 1.0 * MEV / consts::W_ION;
        assert!((n - 42372.9).abs() < 1.0);
    }

    #[test]
    fn angle_units() {
        assert!((90.0 * DEGREE - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
    }

    #[test]
    fn frequency_units() {
        // 2 MHz sampling -> 500 ns period
        let period = 1.0 / (2.0 * MHZ);
        assert!((period / NS - 500.0).abs() < 1e-9);
    }

    #[test]
    fn with_unit_formats() {
        let s = with_unit(3.0 * CM, MM, "mm");
        assert_eq!(s, "30.0000 mm");
    }

    #[test]
    fn diffusion_sigma_scale() {
        // sigma after 1 ms drift: sqrt(2 * D_L * t) ~ 1.2 mm for D_L=7.2cm^2/s
        let sigma = (2.0 * consts::DIFFUSION_L * MS).sqrt();
        assert!(sigma / MM > 1.0 && sigma / MM < 1.5, "sigma={} mm", sigma / MM);
    }
}
