//! Frames and traces: the simulation's output data model (WCT `IFrame`).

use crate::geometry::PlaneId;

/// One plane's dense readout: ADC counts or float signal, row-major
/// (channel × tick).
#[derive(Clone, Debug)]
pub struct PlaneFrame {
    /// Which plane.
    pub plane: PlaneId,
    /// Channels (wires).
    pub nchan: usize,
    /// Ticks.
    pub nticks: usize,
    /// Row-major samples.
    pub data: Vec<f32>,
}

impl PlaneFrame {
    /// Zeroed frame.
    pub fn zeros(plane: PlaneId, nchan: usize, nticks: usize) -> Self {
        Self {
            plane,
            nchan,
            nticks,
            data: vec![0.0; nchan * nticks],
        }
    }

    /// Sample at (channel, tick).
    pub fn at(&self, c: usize, t: usize) -> f32 {
        self.data[c * self.nticks + t]
    }

    /// One channel's waveform.
    pub fn channel(&self, c: usize) -> &[f32] {
        &self.data[c * self.nticks..(c + 1) * self.nticks]
    }

    /// Extract sparse traces: contiguous runs where |sample| exceeds
    /// `threshold`, padded by `pad` ticks each side.
    pub fn traces(&self, threshold: f32, pad: usize) -> Vec<Trace> {
        let mut out = Vec::new();
        for c in 0..self.nchan {
            let wave = self.channel(c);
            let mut t = 0;
            while t < self.nticks {
                if wave[t].abs() > threshold {
                    // find run end
                    let mut end = t;
                    while end < self.nticks && wave[end].abs() > threshold {
                        end += 1;
                    }
                    let lo = t.saturating_sub(pad);
                    let hi = (end + pad).min(self.nticks);
                    out.push(Trace {
                        plane: self.plane,
                        channel: c,
                        tbin: lo,
                        samples: wave[lo..hi].to_vec(),
                    });
                    t = hi;
                } else {
                    t += 1;
                }
            }
        }
        out
    }

    /// Summary statistics (sum, min, max, rms).
    pub fn stats(&self) -> FrameStats {
        let n = self.data.len().max(1);
        let sum: f64 = self.data.iter().map(|&v| v as f64).sum();
        let min = self.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = self.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mean = sum / n as f64;
        let var: f64 = self
            .data
            .iter()
            .map(|&v| (v as f64 - mean) * (v as f64 - mean))
            .sum::<f64>()
            / n as f64;
        FrameStats {
            sum,
            min,
            max,
            rms: var.sqrt(),
        }
    }
}

/// Sparse trace: a run of samples on one channel.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Plane of the channel.
    pub plane: PlaneId,
    /// Channel index.
    pub channel: usize,
    /// First tick of the samples.
    pub tbin: usize,
    /// The samples.
    pub samples: Vec<f32>,
}

/// Frame summary statistics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrameStats {
    /// Sum over all samples.
    pub sum: f64,
    /// Minimum sample.
    pub min: f32,
    /// Maximum sample.
    pub max: f32,
    /// RMS about the mean.
    pub rms: f64,
}

/// A full event: one frame per plane.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Per-plane frames in U, V, W order.
    pub planes: Vec<PlaneFrame>,
    /// Event identifier.
    pub ident: u64,
}

impl Frame {
    /// Frame lookup by plane.
    pub fn plane(&self, id: PlaneId) -> &PlaneFrame {
        &self.planes[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_with_pulse() -> PlaneFrame {
        let mut f = PlaneFrame::zeros(PlaneId::W, 4, 100);
        for t in 40..50 {
            f.data[2 * 100 + t] = 10.0;
        }
        f
    }

    #[test]
    fn accessors() {
        let f = frame_with_pulse();
        assert_eq!(f.at(2, 45), 10.0);
        assert_eq!(f.at(1, 45), 0.0);
        assert_eq!(f.channel(2).len(), 100);
    }

    #[test]
    fn trace_extraction_finds_pulse() {
        let f = frame_with_pulse();
        let traces = f.traces(1.0, 3);
        assert_eq!(traces.len(), 1);
        let tr = &traces[0];
        assert_eq!(tr.channel, 2);
        assert_eq!(tr.tbin, 37);
        assert_eq!(tr.samples.len(), 10 + 6);
    }

    #[test]
    fn trace_extraction_multiple_runs() {
        let mut f = PlaneFrame::zeros(PlaneId::U, 1, 100);
        f.data[10] = 5.0;
        f.data[60] = -5.0;
        let traces = f.traces(1.0, 0);
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].tbin, 10);
        assert_eq!(traces[1].tbin, 60);
    }

    #[test]
    fn trace_pad_clamps_at_edges() {
        let mut f = PlaneFrame::zeros(PlaneId::U, 1, 20);
        f.data[0] = 9.0;
        f.data[19] = 9.0;
        let traces = f.traces(1.0, 5);
        assert_eq!(traces[0].tbin, 0);
        assert_eq!(traces.last().unwrap().tbin + traces.last().unwrap().samples.len(), 20);
    }

    #[test]
    fn stats() {
        let f = frame_with_pulse();
        let s = f.stats();
        assert_eq!(s.sum, 100.0);
        assert_eq!(s.max, 10.0);
        assert_eq!(s.min, 0.0);
        assert!(s.rms > 0.0);
    }

    #[test]
    fn empty_frame_traces() {
        let f = PlaneFrame::zeros(PlaneId::V, 3, 50);
        assert!(f.traces(0.5, 2).is_empty());
    }
}
