//! Electronics-noise simulation — the additive `N(t, x)` term of Eq. 1.
//!
//! WCT's noise model draws per-channel waveforms from a measured
//! amplitude spectrum with random phases.  We parametrize the spectrum
//! (white floor + low-frequency excess + shaper roll-off), generate a
//! Hermitian-symmetric random spectrum per channel, and inverse-FFT —
//! the same frequency-domain construction as production WCT.

use crate::fft::{irfft, Complex};
use crate::rng::{normal, Pcg32};

/// Parametrized noise amplitude spectrum.
#[derive(Clone, Debug)]
pub struct NoiseSpectrum {
    /// RMS scale of the white-noise floor (ADC-equivalent units).
    pub white: f64,
    /// Low-frequency excess amplitude (1/f-like component).
    pub pink: f64,
    /// Shaper roll-off frequency as a fraction of Nyquist (0..1].
    pub rolloff: f64,
    /// Number of ticks per generated waveform.
    pub nticks: usize,
}

impl NoiseSpectrum {
    /// MicroBooNE-ish defaults for a given readout length.
    pub fn standard(nticks: usize) -> Self {
        Self {
            white: 1.0,
            pink: 2.0,
            rolloff: 0.35,
            nticks,
        }
    }

    /// Mean amplitude at frequency bin `k` (0..nticks/2 inclusive).
    pub fn amplitude(&self, k: usize) -> f64 {
        if k == 0 {
            return 0.0; // no DC noise
        }
        let f = k as f64 / (self.nticks as f64 / 2.0); // fraction of Nyquist
        let pink = self.pink / (1.0 + 8.0 * f);
        let shape = 1.0 / (1.0 + (f / self.rolloff).powi(4));
        (self.white + pink) * shape
    }
}

/// Per-channel noise generator.
pub struct NoiseGenerator {
    spectrum: NoiseSpectrum,
    rng: Pcg32,
}

impl NoiseGenerator {
    /// New generator with a seed.
    pub fn new(spectrum: NoiseSpectrum, seed: u64) -> Self {
        Self {
            spectrum,
            rng: Pcg32::seeded(seed),
        }
    }

    /// Generate one channel waveform of `nticks` samples.
    ///
    /// Construction: for each positive-frequency bin draw a complex
    /// amplitude A(k)·(g1 + i·g2)/√2 with g ~ N(0,1), mirror to the
    /// negative frequencies (Hermitian), inverse FFT, take real parts.
    pub fn waveform(&mut self) -> Vec<f64> {
        let n = self.spectrum.nticks;
        let mut spec = vec![Complex::ZERO; n];
        let half = n / 2;
        for k in 1..half {
            let a = self.spectrum.amplitude(k) * (n as f64).sqrt() / std::f64::consts::SQRT_2;
            let re = normal(&mut self.rng, 0.0, 1.0) * a;
            let im = normal(&mut self.rng, 0.0, 1.0) * a;
            spec[k] = Complex::new(re, im);
            spec[n - k] = spec[k].conj();
        }
        if n % 2 == 0 && half > 0 {
            // Nyquist bin must be real
            let a = self.spectrum.amplitude(half) * (n as f64).sqrt();
            spec[half] = Complex::real(normal(&mut self.rng, 0.0, 1.0) * a);
        }
        irfft(&spec)
    }

    /// Generate `nchan` waveforms as a row-major (nchan × nticks) block.
    pub fn frame(&mut self, nchan: usize) -> Vec<f64> {
        let n = self.spectrum.nticks;
        let mut out = Vec::with_capacity(nchan * n);
        for _ in 0..nchan {
            out.extend(self.waveform());
        }
        out
    }

    /// Access the spectrum parameters.
    pub fn spectrum(&self) -> &NoiseSpectrum {
        &self.spectrum
    }

    /// Expected waveform RMS from the spectrum (Parseval).
    pub fn expected_rms(&self) -> f64 {
        let n = self.spectrum.nticks;
        let half = n / 2;
        let mut var = 0.0;
        for k in 1..half {
            // each of the two half-spectrum quadratures contributes
            var += 2.0 * self.spectrum.amplitude(k).powi(2);
        }
        if n % 2 == 0 && half > 0 {
            var += self.spectrum.amplitude(half).powi(2);
        }
        (var / n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_has_zero_mean() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(1024), 1);
        let w = gen.waveform();
        assert_eq!(w.len(), 1024);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        // DC bin is zeroed, so the time-domain mean is exactly ~0
        assert!(mean.abs() < 1e-9, "mean={mean}");
    }

    #[test]
    fn rms_matches_spectrum_expectation() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(2048), 2);
        let expect = gen.expected_rms();
        let mut total = 0.0;
        let reps = 40;
        for _ in 0..reps {
            let w = gen.waveform();
            total += w.iter().map(|v| v * v).sum::<f64>() / w.len() as f64;
        }
        let rms = (total / reps as f64).sqrt();
        assert!(
            (rms - expect).abs() < 0.1 * expect,
            "rms={rms} expect={expect}"
        );
    }

    #[test]
    fn spectrum_rolls_off_at_high_frequency() {
        let s = NoiseSpectrum::standard(1024);
        assert!(s.amplitude(10) > s.amplitude(500));
        assert_eq!(s.amplitude(0), 0.0);
    }

    #[test]
    fn channels_are_uncorrelated() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(1024), 3);
        let a = gen.waveform();
        let b = gen.waveform();
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        let corr = dot / (na * nb);
        assert!(corr.abs() < 0.15, "corr={corr}");
    }

    #[test]
    fn deterministic_by_seed() {
        let w1 = NoiseGenerator::new(NoiseSpectrum::standard(256), 7).waveform();
        let w2 = NoiseGenerator::new(NoiseSpectrum::standard(256), 7).waveform();
        assert_eq!(w1, w2);
        let w3 = NoiseGenerator::new(NoiseSpectrum::standard(256), 8).waveform();
        assert_ne!(w1, w3);
    }

    #[test]
    fn frame_shape() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(128), 5);
        let f = gen.frame(10);
        assert_eq!(f.len(), 1280);
    }

    #[test]
    fn spectral_content_matches_model() {
        // Average the measured spectrum over many waveforms; low bins
        // should carry more power than high bins per the model.
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(512), 11);
        let mut low = 0.0;
        let mut high = 0.0;
        for _ in 0..20 {
            let w = gen.waveform();
            let spec = crate::fft::rfft(&w);
            low += spec[5..25].iter().map(|c| c.norm_sqr()).sum::<f64>();
            high += spec[200..220].iter().map(|c| c.norm_sqr()).sum::<f64>();
        }
        assert!(low > 4.0 * high, "low={low} high={high}");
    }
}
