//! Electronics-noise simulation — the additive `N(t, x)` term of Eq. 1.
//!
//! WCT's noise model draws per-channel waveforms from a measured
//! amplitude spectrum with random phases.  We parametrize the spectrum
//! (white floor + low-frequency excess + shaper roll-off), generate a
//! Hermitian-symmetric random spectrum per channel, and inverse-FFT —
//! the same frequency-domain construction as production WCT.
//!
//! **Planned synthesis.**  The generator holds one cached C2R plan (the
//! full-length complex plan, `Arc`-shared through the
//! [`Planner`](crate::fft::Planner)), a pre-evaluated amplitude table,
//! and a reused spectrum block — the old path called
//! `irfft` → `Plan::new` per *channel*, recomputing twiddles and
//! bit-reversal tables thousands of times per event and allocating
//! three buffers per waveform.  Synthesis is batched: spectra for a
//! block of channels are drawn serially (the RNG draw order **is** the
//! bit-parity contract with the pre-refactor generator, so draws never
//! race), then the inverse transforms — channel-independent — run
//! through a [`SpectralExec`], bit-identical for any thread count.
//! The inverse deliberately uses the full-length complex plan rather
//! than the half-spectrum fast path: its arithmetic is exactly the
//! legacy `irfft`, which is what keeps frames byte-identical across the
//! refactor (asserted by `rust/tests/spectral.rs`).

use crate::fft::{Complex, Plan, Planner, SpectralExec};
use crate::parallel::SendPtr;
use crate::rng::{normal, Pcg32};
use std::sync::{Arc, Mutex};

/// Parametrized noise amplitude spectrum.
#[derive(Clone, Debug)]
pub struct NoiseSpectrum {
    /// RMS scale of the white-noise floor (ADC-equivalent units).
    pub white: f64,
    /// Low-frequency excess amplitude (1/f-like component).
    pub pink: f64,
    /// Shaper roll-off frequency as a fraction of Nyquist (0..1].
    pub rolloff: f64,
    /// Number of ticks per generated waveform.
    pub nticks: usize,
}

impl NoiseSpectrum {
    /// MicroBooNE-ish defaults for a given readout length.
    pub fn standard(nticks: usize) -> Self {
        Self {
            white: 1.0,
            pink: 2.0,
            rolloff: 0.35,
            nticks,
        }
    }

    /// Mean amplitude at frequency bin `k` (0..nticks/2 inclusive).
    pub fn amplitude(&self, k: usize) -> f64 {
        if k == 0 {
            return 0.0; // no DC noise
        }
        let f = k as f64 / (self.nticks as f64 / 2.0); // fraction of Nyquist
        let pink = self.pink / (1.0 + 8.0 * f);
        let shape = 1.0 / (1.0 + (f / self.rolloff).powi(4));
        (self.white + pink) * shape
    }
}

/// How many channels share one drawn-spectrum block per synthesis
/// round, per worker of the dispatching exec.
const BLOCK_CHANNELS_PER_WORKER: usize = 4;

/// Per-channel noise generator with cached plan, amplitude table and
/// reusable spectrum block.
pub struct NoiseGenerator {
    spectrum: NoiseSpectrum,
    rng: Pcg32,
    /// Cached inverse plan for `nticks` (legacy-`irfft` arithmetic).
    plan: Arc<Plan>,
    /// Quadrature amplitude per bin `k in 0..n/2`:
    /// `amplitude(k)·√n/√2` (bin 0 stays zero — no DC noise).
    amp: Vec<f64>,
    /// Real Nyquist amplitude `amplitude(n/2)·√n` (even `n` only).
    amp_nyquist: f64,
    /// Reused per-block spectrum storage (grows once).
    block: Vec<Complex>,
    /// Per-worker Bluestein scratch lanes for the threaded inverse.
    lanes: Vec<Mutex<Vec<Complex>>>,
}

impl NoiseGenerator {
    /// New generator with a seed, planning through the shared cache.
    pub fn new(spectrum: NoiseSpectrum, seed: u64) -> Self {
        Self::with_planner(spectrum, seed, &Planner::shared())
    }

    /// New generator sharing FFT plans through `planner`.
    pub fn with_planner(spectrum: NoiseSpectrum, seed: u64, planner: &Arc<Planner>) -> Self {
        let n = spectrum.nticks;
        let half = n / 2;
        let root_n = (n as f64).sqrt();
        let amp: Vec<f64> = (0..half)
            .map(|k| spectrum.amplitude(k) * root_n / std::f64::consts::SQRT_2)
            .collect();
        let amp_nyquist = if n % 2 == 0 && half > 0 {
            spectrum.amplitude(half) * root_n
        } else {
            0.0
        };
        Self {
            rng: Pcg32::seeded(seed),
            plan: planner.plan(n),
            amp,
            amp_nyquist,
            block: Vec::new(),
            lanes: Vec::new(),
            spectrum,
        }
    }

    /// Rewind the generator onto a new seed (the noise stage reuses one
    /// generator — plan, tables, buffers — across events, swapping only
    /// the RNG state, which is exactly what a fresh construction did).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
    }

    /// Draw one channel's Hermitian spectrum into `spec` (len nticks):
    /// for each positive-frequency bin a complex amplitude
    /// A(k)·(g1 + i·g2)/√2 with g ~ N(0,1), mirrored to the negative
    /// frequencies; real Nyquist bin for even lengths.  This is the
    /// only RNG-consuming step, so the draw order here fixes the byte
    /// stream.  (Free-standing over disjoint fields so a block slice of
    /// `self.block` can be filled while `self.rng` advances.)
    fn draw_spectrum(
        rng: &mut Pcg32,
        amp: &[f64],
        amp_nyquist: f64,
        n: usize,
        spec: &mut [Complex],
    ) {
        let half = n / 2;
        spec.fill(Complex::ZERO);
        for k in 1..half {
            let a = amp[k];
            let re = normal(rng, 0.0, 1.0) * a;
            let im = normal(rng, 0.0, 1.0) * a;
            spec[k] = Complex::new(re, im);
            spec[n - k] = spec[k].conj();
        }
        if n % 2 == 0 && half > 0 {
            // Nyquist bin must be real
            spec[half] = Complex::real(normal(rng, 0.0, 1.0) * amp_nyquist);
        }
    }

    /// Batched synthesis core: draw spectra for blocks of channels
    /// (serial — RNG order is the contract), inverse-transform each
    /// channel through the cached plan (dispatched over `exec`, bit-
    /// identical for any worker count), and hand each finished
    /// time-domain channel to `write(channel, waveform)` as the real
    /// parts of the transformed block slice.
    fn synth(
        &mut self,
        nchan: usize,
        exec: SpectralExec<'_>,
        write: impl Fn(usize, &[Complex]) + Sync,
    ) {
        let n = self.spectrum.nticks;
        if n == 0 || nchan == 0 {
            return;
        }
        let conc = exec.concurrency();
        let block = (conc * BLOCK_CHANNELS_PER_WORKER).clamp(1, nchan);
        self.block.resize(block * n, Complex::ZERO);
        while self.lanes.len() < conc {
            self.lanes.push(Mutex::new(Vec::new()));
        }
        let mut done = 0usize;
        while done < nchan {
            let nb = block.min(nchan - done);
            for b in 0..nb {
                Self::draw_spectrum(
                    &mut self.rng,
                    &self.amp,
                    self.amp_nyquist,
                    n,
                    &mut self.block[b * n..(b + 1) * n],
                );
            }
            let ptr = SendPtr(self.block.as_mut_ptr());
            let plan = &self.plan;
            let lanes = &self.lanes;
            exec.run_chunks(nb, |li, range| {
                let mut conv = lanes[li].lock().unwrap();
                for b in range {
                    // channels are disjoint slices of the block buffer
                    let chan =
                        unsafe { std::slice::from_raw_parts_mut(ptr.get().add(b * n), n) };
                    plan.inverse_scratch(chan, &mut conv);
                    write(done + b, chan);
                }
            });
            done += nb;
        }
    }

    /// Generate one channel waveform of `nticks` samples (allocating
    /// convenience; streams go through [`frame_into`](Self::frame_into)
    /// or the session noise stage).
    pub fn waveform(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.frame_into(1, &mut out, SpectralExec::serial());
        out
    }

    /// Generate `nchan` waveforms into `out` as a row-major
    /// (nchan × nticks) block — zero heap allocations once the
    /// generator and `out` have warmed up (serial exec; threaded execs
    /// add only the pool's per-dispatch bookkeeping).
    pub fn frame_into(&mut self, nchan: usize, out: &mut Vec<f64>, exec: SpectralExec<'_>) {
        let n = self.spectrum.nticks;
        out.resize(nchan * n, 0.0);
        let optr = SendPtr(out.as_mut_ptr());
        self.synth(nchan, exec, |chan_idx, chan| {
            let dst =
                unsafe { std::slice::from_raw_parts_mut(optr.get().add(chan_idx * n), n) };
            for (d, c) in dst.iter_mut().zip(chan) {
                *d = c.re;
            }
        });
    }

    /// Generate `nchan` waveforms as a row-major (nchan × nticks) block
    /// (allocating convenience over [`frame_into`](Self::frame_into)).
    pub fn frame(&mut self, nchan: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.frame_into(nchan, &mut out, SpectralExec::serial());
        out
    }

    /// Add `nchan` synthesized waveforms, scaled by `gain`, onto a
    /// row-major (nchan × nticks) `f32` frame block — the session noise
    /// stage's zero-allocation path.  The per-sample arithmetic
    /// (`sample += (wave as f32) * gain`) is the legacy stage's, so
    /// frames stay byte-identical.
    pub fn add_to_frame(
        &mut self,
        frame: &mut [f32],
        nchan: usize,
        gain: f32,
        exec: SpectralExec<'_>,
    ) {
        let n = self.spectrum.nticks;
        assert_eq!(frame.len(), nchan * n, "frame shape mismatch");
        let fptr = SendPtr(frame.as_mut_ptr());
        self.synth(nchan, exec, |chan_idx, chan| {
            let dst =
                unsafe { std::slice::from_raw_parts_mut(fptr.get().add(chan_idx * n), n) };
            for (d, c) in dst.iter_mut().zip(chan) {
                *d += (c.re as f32) * gain;
            }
        });
    }

    /// Access the spectrum parameters.
    pub fn spectrum(&self) -> &NoiseSpectrum {
        &self.spectrum
    }

    /// Expected waveform RMS from the spectrum (Parseval).
    pub fn expected_rms(&self) -> f64 {
        let n = self.spectrum.nticks;
        let half = n / 2;
        let mut var = 0.0;
        for k in 1..half {
            // each of the two half-spectrum quadratures contributes
            var += 2.0 * self.spectrum.amplitude(k).powi(2);
        }
        if n % 2 == 0 && half > 0 {
            var += self.spectrum.amplitude(half).powi(2);
        }
        (var / n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_has_zero_mean() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(1024), 1);
        let w = gen.waveform();
        assert_eq!(w.len(), 1024);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        // DC bin is zeroed, so the time-domain mean is exactly ~0
        assert!(mean.abs() < 1e-9, "mean={mean}");
    }

    #[test]
    fn rms_matches_spectrum_expectation() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(2048), 2);
        let expect = gen.expected_rms();
        let mut total = 0.0;
        let reps = 40;
        for _ in 0..reps {
            let w = gen.waveform();
            total += w.iter().map(|v| v * v).sum::<f64>() / w.len() as f64;
        }
        let rms = (total / reps as f64).sqrt();
        assert!(
            (rms - expect).abs() < 0.1 * expect,
            "rms={rms} expect={expect}"
        );
    }

    #[test]
    fn spectrum_rolls_off_at_high_frequency() {
        let s = NoiseSpectrum::standard(1024);
        assert!(s.amplitude(10) > s.amplitude(500));
        assert_eq!(s.amplitude(0), 0.0);
    }

    #[test]
    fn channels_are_uncorrelated() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(1024), 3);
        let a = gen.waveform();
        let b = gen.waveform();
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        let corr = dot / (na * nb);
        assert!(corr.abs() < 0.15, "corr={corr}");
    }

    #[test]
    fn deterministic_by_seed() {
        let w1 = NoiseGenerator::new(NoiseSpectrum::standard(256), 7).waveform();
        let w2 = NoiseGenerator::new(NoiseSpectrum::standard(256), 7).waveform();
        assert_eq!(w1, w2);
        let w3 = NoiseGenerator::new(NoiseSpectrum::standard(256), 8).waveform();
        assert_ne!(w1, w3);
    }

    #[test]
    fn reseed_equals_fresh_construction() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(256), 7);
        let _ = gen.frame(3); // advance + dirty every buffer
        gen.reseed(7);
        let again = gen.frame(3);
        let fresh = NoiseGenerator::new(NoiseSpectrum::standard(256), 7).frame(3);
        for (a, b) in again.iter().zip(&fresh) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn frame_shape() {
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(128), 5);
        let f = gen.frame(10);
        assert_eq!(f.len(), 1280);
    }

    #[test]
    fn frame_equals_waveform_sequence() {
        // one draw stream, two consumption patterns — same bytes
        let mut a = NoiseGenerator::new(NoiseSpectrum::standard(200), 9);
        let mut b = NoiseGenerator::new(NoiseSpectrum::standard(200), 9);
        let f = a.frame(5);
        for ch in 0..5 {
            let w = b.waveform();
            for (x, y) in f[ch * 200..(ch + 1) * 200].iter().zip(&w) {
                assert_eq!(x.to_bits(), y.to_bits(), "channel {ch}");
            }
        }
    }

    #[test]
    fn threaded_frame_is_bit_identical() {
        use crate::parallel::{ExecPolicy, ThreadPool};
        let nticks = 250; // Bluestein length: exercises the conv lanes
        let mut serial = NoiseGenerator::new(NoiseSpectrum::standard(nticks), 21);
        let mut threaded = NoiseGenerator::new(NoiseSpectrum::standard(nticks), 21);
        let sf = serial.frame(13);
        let pool = ThreadPool::new(4);
        let mut tf = Vec::new();
        threaded.frame_into(13, &mut tf, SpectralExec::new(&pool, ExecPolicy::Threads(4)));
        for (i, (a, b)) in sf.iter().zip(&tf).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "sample {i}");
        }
    }

    #[test]
    fn add_to_frame_matches_stage_arithmetic() {
        let nticks = 128;
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(nticks), 4);
        let mut frame = vec![0.5f32; 3 * nticks];
        gen.add_to_frame(&mut frame, 3, 1e-3, SpectralExec::serial());
        // reference: waveform loop with the legacy stage arithmetic
        let mut gen2 = NoiseGenerator::new(NoiseSpectrum::standard(nticks), 4);
        let mut expect = vec![0.5f32; 3 * nticks];
        for c in 0..3 {
            let wave = gen2.waveform();
            for (s, n) in expect[c * nticks..(c + 1) * nticks].iter_mut().zip(wave) {
                *s += n as f32 * 1e-3;
            }
        }
        for (a, b) in frame.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn spectral_content_matches_model() {
        // Average the measured spectrum over many waveforms; low bins
        // should carry more power than high bins per the model.
        let mut gen = NoiseGenerator::new(NoiseSpectrum::standard(512), 11);
        let mut low = 0.0;
        let mut high = 0.0;
        for _ in 0..20 {
            let w = gen.waveform();
            let spec = crate::fft::rfft(&w);
            low += spec[5..25].iter().map(|c| c.norm_sqr()).sum::<f64>();
            high += spec[200..220].iter().map(|c| c.norm_sqr()).sum::<f64>();
        }
        assert!(low > 4.0 * high, "low={low} high={high}");
    }
}
