//! The scenario engine: named workloads over multi-APA detector
//! layouts, with expected-statistics witnesses and an APA-sharded
//! execution path.
//!
//! The source paper benchmarks exactly one workload — ~100k cosmic-ray
//! depos on one plane set — but its follow-up studies
//! (arXiv:2203.02479, arXiv:2304.01841) stress that
//! portable-performance conclusions only hold when measured across
//! *diverse* workloads and at multi-APA scale.  This module supplies
//! both axes:
//!
//! * [`Scenario`] — a named depo workload generated over an
//!   [`ApaLayout`](crate::geometry::ApaLayout) in global coordinates,
//!   paired with a [`ScenarioWitness`] (expected depo-count and
//!   charge-scale bounds) that tests and the benchmark harness check
//!   before trusting a run.  Eight built-ins cover the physics space
//!   ([`BUILTIN_SCENARIOS`]): beam tracks crossing every APA, cosmic
//!   showers, beam⊕cosmic pile-up, noise-only pedestal events, a
//!   hotspot blob that lands everything on one APA (the sharding
//!   worst case), the production-shaped `full-detector` workload
//!   (beam ⊕ Poisson-pileup cosmics, ProtoDUNE-SP scale under
//!   `--preset full-detector`), `depo-replay` for one recorded
//!   sample, and `depo-stream` for a directory of recorded samples
//!   replayed in sequence (`--depo-dir`).
//! * [`sharded`] — [`ShardedSession`]: fan an event's depos out to
//!   per-APA shards, run each shard through its own
//!   [`SimSession`](crate::session::SimSession) (serially or over a
//!   pull-based worker pool), and scatter-gather the shard frames into
//!   one order-independent, digest-stable event frame.
//!
//! Scenarios register in the string-keyed
//! [`Registry`](crate::session::Registry) exactly like backends,
//! strategies and stages — a new scenario registers in one place and
//! the CLI (`wire-cell scenarios`, `--scenario`), the throughput
//! engine, and `harness::scenario_matrix` all resolve it by name.
//! `docs/SCENARIOS.md` is the user-facing catalog.
//!
//! # Examples
//!
//! ```
//! use wirecell::config::{FluctuationMode, SimConfig};
//! use wirecell::scenario::{apa_seed, Scenario, ShardExec, ShardedSession};
//! use wirecell::session::Registry;
//!
//! let mut cfg = SimConfig::default();
//! cfg.scenario = "beam-track".into();
//! cfg.apas = 2;
//! cfg.target_depos = 300;
//! cfg.fluctuation = FluctuationMode::None;
//! cfg.pool_size = 1 << 14;
//!
//! let registry = Registry::with_defaults();
//! let scenario = registry.make_scenario(&cfg)?;
//! let mut session = ShardedSession::new(&cfg, ShardExec::Serial)?;
//! let depos = scenario.generate(session.layout(), cfg.seed);
//! scenario.witness().check(&depos).map_err(anyhow::Error::msg)?;
//! let report = session.run_event(cfg.seed, &depos)?;
//! assert_eq!(report.shards.len(), 2);
//! assert_ne!(apa_seed(cfg.seed, 1), cfg.seed);
//! # Ok::<(), anyhow::Error>(())
//! ```

mod replay;
pub mod sharded;
mod sources;

pub use replay::{DepoReplayScenario, DepoStreamScenario};
pub use sharded::{
    apa_seed, shard_depos, ShardExec, ShardStats, ShardedReport, ShardedSession,
};
pub use sources::{
    BeamTrackScenario, CosmicShowerScenario, FullDetectorScenario, HotspotScenario,
    NoiseOnlyScenario, PileupMixScenario,
};

use crate::depo::Depo;
use crate::geometry::ApaLayout;

/// The built-in scenario vocabulary, registry-key order — what
/// `Registry::with_defaults` registers and `wire-cell scenarios`
/// lists.  Custom scenarios register at run time via
/// [`Registry::register_scenario`](crate::session::Registry::register_scenario).
pub const BUILTIN_SCENARIOS: &[&str] = &[
    "beam-track",
    "cosmic-shower",
    "depo-replay",
    "depo-stream",
    "full-detector",
    "hotspot",
    "noise-only",
    "pileup-mix",
];

/// Expected-statistics bounds for a scenario's generated workload —
/// the cheap sanity witness tests and `harness::scenario_matrix` check
/// before trusting a run's timings or digests.
///
/// All built-in generators are deterministic by seed (same seed, same
/// depos, bit for bit); the witness bounds the *statistical shape* a
/// fresh seed must land in: depo count near the configured target and
/// per-depo charge on the MIP ionization scale.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioWitness {
    /// Inclusive depo-count band `(min, max)`.
    pub count: (usize, usize),
    /// Inclusive mean-charge band per depo, electrons `(min, max)`;
    /// checked only when the count may be non-zero.
    pub mean_charge: (f64, f64),
}

impl ScenarioWitness {
    /// Check a generated depo set against the bounds.
    pub fn check(&self, depos: &[Depo]) -> Result<(), String> {
        let n = depos.len();
        if n < self.count.0 || n > self.count.1 {
            return Err(format!(
                "depo count {n} outside witness band [{}, {}]",
                self.count.0, self.count.1
            ));
        }
        if n == 0 {
            return Ok(());
        }
        let mean = depos.iter().map(|d| d.charge).sum::<f64>() / n as f64;
        if mean < self.mean_charge.0 || mean > self.mean_charge.1 {
            return Err(format!(
                "mean charge {mean:.1} e outside witness band [{:.1}, {:.1}]",
                self.mean_charge.0, self.mean_charge.1
            ));
        }
        Ok(())
    }
}

/// A named workload: generates one event's depos in *global*
/// coordinates over a multi-APA layout, and states the statistical
/// shape the output must have.
///
/// Implementations must be deterministic by seed — the sharded
/// execution path and the throughput engine both rely on
/// `(scenario, layout, seed)` fully determining the depo set.  They
/// must also be `Send`: throughput workers own one scenario each.
pub trait Scenario: Send {
    /// Registry name of this scenario ("beam-track", ...).
    fn name(&self) -> &str;

    /// Generate one event's depos in global coordinates for `layout`.
    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo>;

    /// Generate the depos for event number `seq` of a stream.
    ///
    /// Synthetic generators are seed-driven and position-blind, so the
    /// default simply forwards to [`generate`](Scenario::generate) —
    /// the stream position is already folded into the per-event seed
    /// by [`event_seed`](crate::throughput::event_seed).  Replay-style
    /// scenarios (notably [`DepoStreamScenario`]) override this to
    /// select the `seq`-th recorded sample, which is what makes a
    /// replayed stream deterministic for any worker count: workers
    /// receive `(seq, seed)` tickets, never "whatever file is next".
    fn generate_seq(&self, layout: &ApaLayout, seed: u64, seq: u64) -> Vec<Depo> {
        let _ = seq;
        self.generate(layout, seed)
    }

    /// Expected-statistics bounds for the generated set.
    fn witness(&self) -> ScenarioWitness;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depo::Depo;

    #[test]
    fn witness_checks_count_and_charge() {
        let w = ScenarioWitness {
            count: (2, 4),
            mean_charge: (1000.0, 2000.0),
        };
        let mk = |n: usize, q: f64| -> Vec<Depo> {
            (0..n)
                .map(|i| Depo::point(0.0, [0.0; 3], q, i as u64))
                .collect()
        };
        assert!(w.check(&mk(3, 1500.0)).is_ok());
        assert!(w.check(&mk(1, 1500.0)).unwrap_err().contains("count"));
        assert!(w.check(&mk(5, 1500.0)).unwrap_err().contains("count"));
        assert!(w.check(&mk(3, 10.0)).unwrap_err().contains("charge"));
        // a zero-count witness skips the charge band
        let empty = ScenarioWitness {
            count: (0, 0),
            mean_charge: (0.0, 0.0),
        };
        assert!(empty.check(&[]).is_ok());
        assert!(empty.check(&mk(1, 0.0)).is_err());
    }

    #[test]
    fn builtin_list_is_sorted_and_distinct() {
        // registry keys render in BTreeMap order; keep the const in the
        // same order so docs and listings agree
        let mut sorted = BUILTIN_SCENARIOS.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, BUILTIN_SCENARIOS.to_vec());
        assert!(BUILTIN_SCENARIOS.len() >= 5);
    }
}
