//! **depo-replay** and **depo-stream** — drive recorded depo samples
//! through the same session / sharding / mixed-traffic machinery as
//! the synthetic generators.
//!
//! [`DepoReplayScenario`] replays *one* recorded sample: the set is
//! loaded once (from a `depo/io.rs` JSON file via
//! [`DepoReplayScenario::from_file`], or handed over in memory) and
//! every event replays it verbatim — `generate` ignores the seed, so a
//! replayed event is bit-identical to running the recorded list
//! directly; the roundtrip witness test in `rust/tests/traffic.rs`
//! pins exactly that.  The depo JSON format stores every f64 in
//! shortest-roundtrip form, so file → memory → file loses nothing.
//!
//! [`DepoStreamScenario`] generalizes replay to a *sustained stream*:
//! `--depo-dir <dir>` loads every `*.json` depo file in the directory
//! in sorted filename order, and event `seq` of a stream replays
//! sample `seq % len` via
//! [`Scenario::generate_seq`](super::Scenario::generate_seq).  The
//! sequence position — not worker arrival order and not the seed —
//! selects the sample, so a streamed run stays deterministic for any
//! worker count, in batch mode and behind the serve daemon alike.

use super::{Scenario, ScenarioWitness};
use crate::depo::{read_depo_file, Depo};
use crate::geometry::ApaLayout;
use std::path::Path;

/// Replays a fixed depo list as a [`Scenario`] (see module docs).
///
/// Registered as `depo-replay`; without a `depo_file` configured the
/// replay set is empty and the scenario behaves like `noise-only`.
pub struct DepoReplayScenario {
    depos: Vec<Depo>,
}

impl DepoReplayScenario {
    /// Replay an in-memory depo list.
    pub fn new(depos: Vec<Depo>) -> Self {
        Self { depos }
    }

    /// Replay a depo file written by `depo::write_depo_file`.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let depos =
            read_depo_file(path).map_err(|e| format!("depo file {}: {e}", path.display()))?;
        Ok(Self::new(depos))
    }

    /// Number of depos replayed per event.
    pub fn len(&self) -> usize {
        self.depos.len()
    }

    /// True when the replay set is empty.
    pub fn is_empty(&self) -> bool {
        self.depos.is_empty()
    }
}

impl Scenario for DepoReplayScenario {
    fn name(&self) -> &str {
        "depo-replay"
    }

    fn generate(&self, _layout: &ApaLayout, _seed: u64) -> Vec<Depo> {
        // literal replay: the seed is deliberately ignored
        self.depos.clone()
    }

    fn witness(&self) -> ScenarioWitness {
        let n = self.depos.len();
        if n == 0 {
            return ScenarioWitness {
                count: (0, 0),
                mean_charge: (0.0, 0.0),
            };
        }
        let mean = self.depos.iter().map(|d| d.charge).sum::<f64>() / n as f64;
        // the replayed mean is exact; leave a hair of slack for the
        // witness's own summation order
        let slack = mean.abs().max(1.0) * 1e-9;
        ScenarioWitness {
            count: (n, n),
            mean_charge: (mean - slack, mean + slack),
        }
    }
}

/// Replays a directory of recorded depo samples in deterministic
/// (sorted-filename) sequence (see module docs).
///
/// Registered as `depo-stream`; configure with `--depo-dir <dir>`.
/// Without a directory the stream is empty and every event behaves
/// like `noise-only`.
pub struct DepoStreamScenario {
    sets: Vec<Vec<Depo>>,
}

impl DepoStreamScenario {
    /// Stream over in-memory samples, replayed round-robin by event
    /// sequence number.
    pub fn new(sets: Vec<Vec<Depo>>) -> Self {
        Self { sets }
    }

    /// Load every `*.json` depo file under `dir` (non-recursive), in
    /// sorted filename order.  Errors if the directory is unreadable,
    /// contains no depo files, or any file fails to parse — a silent
    /// empty stream would masquerade as noise-only.
    pub fn from_dir(dir: &Path) -> Result<Self, String> {
        let entries =
            std::fs::read_dir(dir).map_err(|e| format!("depo dir {}: {e}", dir.display()))?;
        let mut paths: Vec<std::path::PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(format!(
                "depo dir {}: no *.json depo files",
                dir.display()
            ));
        }
        let mut sets = Vec::with_capacity(paths.len());
        for p in &paths {
            sets.push(
                read_depo_file(p).map_err(|e| format!("depo file {}: {e}", p.display()))?,
            );
        }
        Ok(Self::new(sets))
    }

    /// Number of recorded samples in the stream cycle.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// True when the stream holds no samples.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }
}

impl Scenario for DepoStreamScenario {
    fn name(&self) -> &str {
        "depo-stream"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        // single-event entry points (simulate, scenario_matrix) see
        // the head of the stream
        self.generate_seq(layout, seed, 0)
    }

    fn generate_seq(&self, _layout: &ApaLayout, _seed: u64, seq: u64) -> Vec<Depo> {
        if self.sets.is_empty() {
            return Vec::new();
        }
        // literal replay of sample seq % len: seed-blind by design
        self.sets[(seq % self.sets.len() as u64) as usize].clone()
    }

    fn witness(&self) -> ScenarioWitness {
        if self.sets.is_empty() || self.sets.iter().all(|s| s.is_empty()) {
            return ScenarioWitness {
                count: (0, 0),
                mean_charge: (0.0, 0.0),
            };
        }
        // band covering every sample in the cycle: any event of the
        // stream must land inside
        let mut count = (usize::MAX, 0usize);
        let mut charge = (f64::INFINITY, f64::NEG_INFINITY);
        for set in &self.sets {
            count.0 = count.0.min(set.len());
            count.1 = count.1.max(set.len());
            if !set.is_empty() {
                let mean = set.iter().map(|d| d.charge).sum::<f64>() / set.len() as f64;
                charge.0 = charge.0.min(mean);
                charge.1 = charge.1.max(mean);
            }
        }
        let slack = charge.1.abs().max(1.0) * 1e-9;
        ScenarioWitness {
            count,
            mean_charge: (charge.0 - slack, charge.1 + slack),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Detector;

    fn sample() -> Vec<Depo> {
        (0..20)
            .map(|i| {
                Depo::point(
                    i as f64 * 10.0,
                    [50.0 + i as f64, -5.0, 3.0 * i as f64],
                    4_000.0 + 7.0 * i as f64,
                    i,
                )
            })
            .collect()
    }

    #[test]
    fn replay_is_verbatim_and_seed_blind() {
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        let scn = DepoReplayScenario::new(sample());
        let a = scn.generate(&lay, 1);
        let b = scn.generate(&lay, 999);
        assert_eq!(a, sample());
        assert_eq!(a, b, "replay must ignore the seed");
        scn.witness().check(&a).unwrap();
        assert_eq!(scn.len(), 20);
        assert!(!scn.is_empty());
    }

    #[test]
    fn empty_replay_passes_its_own_witness() {
        let scn = DepoReplayScenario::new(Vec::new());
        assert!(scn.is_empty());
        scn.witness().check(&[]).unwrap();
    }

    #[test]
    fn file_roundtrip_reproduces_the_list() {
        let path = std::env::temp_dir().join("wct_replay_scenario_test.json");
        crate::depo::write_depo_file(&path, &sample()).unwrap();
        let scn = DepoReplayScenario::from_file(&path).unwrap();
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        assert_eq!(scn.generate(&lay, 0), sample());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_a_clear_error() {
        let err = DepoReplayScenario::from_file(Path::new("/nonexistent/depos.json"))
            .err()
            .unwrap();
        assert!(err.contains("depos.json"), "{err}");
    }

    fn stream_sets() -> Vec<Vec<Depo>> {
        (0..3)
            .map(|k| {
                (0..(10 + k))
                    .map(|i| {
                        Depo::point(
                            i as f64,
                            [40.0 + i as f64, 0.0, 2.0 * k as f64],
                            3_000.0 + 500.0 * k as f64,
                            i as u64,
                        )
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn stream_replays_by_sequence_not_seed() {
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        let sets = stream_sets();
        let scn = DepoStreamScenario::new(sets.clone());
        assert_eq!(scn.len(), 3);
        for seq in 0..7u64 {
            let got = scn.generate_seq(&lay, 0xABCD + seq, seq);
            assert_eq!(got, sets[(seq % 3) as usize], "seq {seq}");
            scn.witness().check(&got).unwrap();
        }
        // generate() is the head of the stream
        assert_eq!(scn.generate(&lay, 42), sets[0]);
    }

    #[test]
    fn stream_witness_bands_cover_every_sample() {
        let scn = DepoStreamScenario::new(stream_sets());
        let w = scn.witness();
        assert_eq!(w.count, (10, 12));
        assert!(w.mean_charge.0 <= 3_000.0 && w.mean_charge.1 >= 4_000.0);
        // empty stream has the noise-only witness
        let empty = DepoStreamScenario::new(Vec::new());
        assert!(empty.is_empty());
        empty.witness().check(&[]).unwrap();
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        assert!(empty.generate_seq(&lay, 0, 5).is_empty());
    }

    #[test]
    fn stream_from_dir_loads_sorted_json_files() {
        let dir = std::env::temp_dir().join("wct_depo_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let sets = stream_sets();
        // write out of order; sorted filenames must decide the sequence
        crate::depo::write_depo_file(&dir.join("evt_002.json"), &sets[2]).unwrap();
        crate::depo::write_depo_file(&dir.join("evt_000.json"), &sets[0]).unwrap();
        crate::depo::write_depo_file(&dir.join("evt_001.json"), &sets[1]).unwrap();
        std::fs::write(dir.join("README.txt"), "not a depo file").unwrap();
        let scn = DepoStreamScenario::from_dir(&dir).unwrap();
        assert_eq!(scn.len(), 3);
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        for seq in 0..3u64 {
            assert_eq!(scn.generate_seq(&lay, 0, seq), sets[seq as usize]);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_dir_errors_are_clear() {
        let err = DepoStreamScenario::from_dir(Path::new("/nonexistent/depodir"))
            .err()
            .unwrap();
        assert!(err.contains("depodir"), "{err}");
        let empty = std::env::temp_dir().join("wct_depo_stream_empty_test");
        std::fs::create_dir_all(&empty).unwrap();
        let err = DepoStreamScenario::from_dir(&empty).err().unwrap();
        assert!(err.contains("no *.json"), "{err}");
        std::fs::remove_dir_all(&empty).ok();
    }
}
