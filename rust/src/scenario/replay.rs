//! **depo-replay** — drive a recorded depo sample through the same
//! session / sharding / mixed-traffic machinery as the synthetic
//! generators.
//!
//! The replay set is loaded once (from a `depo/io.rs` JSON file via
//! [`DepoReplayScenario::from_file`], or handed over in memory) and
//! every event replays it verbatim: `generate` ignores the seed, so a
//! replayed event is bit-identical to running the recorded list
//! directly — the roundtrip witness test in `rust/tests/traffic.rs`
//! pins exactly that.  The depo JSON format stores every f64 in
//! shortest-roundtrip form, so file → memory → file loses nothing.

use super::{Scenario, ScenarioWitness};
use crate::depo::{read_depo_file, Depo};
use crate::geometry::ApaLayout;
use std::path::Path;

/// Replays a fixed depo list as a [`Scenario`] (see module docs).
///
/// Registered as `depo-replay`; without a `depo_file` configured the
/// replay set is empty and the scenario behaves like `noise-only`.
pub struct DepoReplayScenario {
    depos: Vec<Depo>,
}

impl DepoReplayScenario {
    /// Replay an in-memory depo list.
    pub fn new(depos: Vec<Depo>) -> Self {
        Self { depos }
    }

    /// Replay a depo file written by `depo::write_depo_file`.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let depos =
            read_depo_file(path).map_err(|e| format!("depo file {}: {e}", path.display()))?;
        Ok(Self::new(depos))
    }

    /// Number of depos replayed per event.
    pub fn len(&self) -> usize {
        self.depos.len()
    }

    /// True when the replay set is empty.
    pub fn is_empty(&self) -> bool {
        self.depos.is_empty()
    }
}

impl Scenario for DepoReplayScenario {
    fn name(&self) -> &str {
        "depo-replay"
    }

    fn generate(&self, _layout: &ApaLayout, _seed: u64) -> Vec<Depo> {
        // literal replay: the seed is deliberately ignored
        self.depos.clone()
    }

    fn witness(&self) -> ScenarioWitness {
        let n = self.depos.len();
        if n == 0 {
            return ScenarioWitness {
                count: (0, 0),
                mean_charge: (0.0, 0.0),
            };
        }
        let mean = self.depos.iter().map(|d| d.charge).sum::<f64>() / n as f64;
        // the replayed mean is exact; leave a hair of slack for the
        // witness's own summation order
        let slack = mean.abs().max(1.0) * 1e-9;
        ScenarioWitness {
            count: (n, n),
            mean_charge: (mean - slack, mean + slack),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Detector;

    fn sample() -> Vec<Depo> {
        (0..20)
            .map(|i| {
                Depo::point(
                    i as f64 * 10.0,
                    [50.0 + i as f64, -5.0, 3.0 * i as f64],
                    4_000.0 + 7.0 * i as f64,
                    i,
                )
            })
            .collect()
    }

    #[test]
    fn replay_is_verbatim_and_seed_blind() {
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        let scn = DepoReplayScenario::new(sample());
        let a = scn.generate(&lay, 1);
        let b = scn.generate(&lay, 999);
        assert_eq!(a, sample());
        assert_eq!(a, b, "replay must ignore the seed");
        scn.witness().check(&a).unwrap();
        assert_eq!(scn.len(), 20);
        assert!(!scn.is_empty());
    }

    #[test]
    fn empty_replay_passes_its_own_witness() {
        let scn = DepoReplayScenario::new(Vec::new());
        assert!(scn.is_empty());
        scn.witness().check(&[]).unwrap();
    }

    #[test]
    fn file_roundtrip_reproduces_the_list() {
        let path = std::env::temp_dir().join("wct_replay_scenario_test.json");
        crate::depo::write_depo_file(&path, &sample()).unwrap();
        let scn = DepoReplayScenario::from_file(&path).unwrap();
        let lay = ApaLayout::for_detector(&Detector::test_small(), 1);
        assert_eq!(scn.generate(&lay, 0), sample());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_a_clear_error() {
        let err = DepoReplayScenario::from_file(Path::new("/nonexistent/depos.json"))
            .err()
            .unwrap();
        assert!(err.contains("depos.json"), "{err}");
    }
}
