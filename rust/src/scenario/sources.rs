//! The built-in scenario generators.
//!
//! Each produces one event's depos in *global* coordinates over an
//! [`ApaLayout`] and is deterministic by seed.  The physics rationale
//! for each workload (and worked CLI examples) lives in
//! `docs/SCENARIOS.md`; the statistical bounds live in each
//! [`witness`](Scenario::witness).

use super::{Scenario, ScenarioWitness};
use crate::depo::{CosmicSource, Depo, DepoSource, TrackDepoSource};
use crate::geometry::ApaLayout;
use crate::physics::MipLoss;
use crate::rng::{normal, Pcg32, UniformRng};
use crate::units::MM;

/// Splitmix-style golden-ratio increment for deriving per-track and
/// per-tile sub-seeds from the event seed.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// MIP ionization per mm of track, electrons — the scale the witness
/// charge bands are anchored on (`TrackDepoSource` draws ~3–15k e per
/// 1 mm step; see `depo::track` tests).
const MIP_E_PER_MM: (f64, f64) = (2_000.0, 25_000.0);

/// Largest x a depo may take so its drift still ends inside the
/// readout window (shared aiming helper for the generators).
///
/// `CosmicSource::usable_drift` encodes the same constraint tightened
/// by its own arrival window; this version uses a flat 0.7·readout
/// margin because the beam/hotspot generators spread arrivals over at
/// most 0.1·readout.  If the readout model changes, change both.
fn usable_drift_x(det: &crate::geometry::Detector) -> f64 {
    let readout = det.nticks as f64 * det.tick;
    (det.response_plane_x + 0.7 * readout * det.drift_speed).min(det.max_drift())
}

/// **beam-track** — a spill of forward-going MIP tracks entering at
/// the upstream face and crossing *every* APA along z (the
/// ProtoDUNE-SP test-beam shape).  This is the scenario that exercises
/// shard boundaries hardest: each track deposits charge in every APA,
/// so a sharding bug shows up as a digest mismatch immediately.
pub struct BeamTrackScenario {
    det: crate::geometry::Detector,
    target: usize,
    napas: usize,
}

impl BeamTrackScenario {
    /// Beam workload sized to roughly `target` depos over `napas` APAs.
    pub fn new(det: crate::geometry::Detector, target: usize, napas: usize) -> Self {
        Self {
            det,
            target: target.max(1),
            napas: napas.max(1),
        }
    }

    /// Step length chosen so the whole spill lands near the target
    /// depo count whatever the row length: at least 1 mm, stretched
    /// when the target is smaller than the row is long.
    fn step_for(&self, zlen: f64) -> f64 {
        (zlen / self.target as f64).max(1.0 * MM)
    }
}

impl Scenario for BeamTrackScenario {
    fn name(&self) -> &str {
        "beam-track"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        let (zlo, zhi) = layout.z_range();
        let zlen = zhi - zlo;
        let step = self.step_for(zlen);
        let per_track = ((zlen / step) as usize).max(1);
        let ntracks = (self.target / per_track).max(1);
        let (ylo, yhi) = self.det.transverse_extent();
        let yspan = yhi - ylo;
        let rx = self.det.response_plane_x;
        let xmax = usable_drift_x(&self.det);
        let readout = self.det.nticks as f64 * self.det.tick;
        let spill = 0.1 * readout;
        let mut rng = Pcg32::seeded(seed ^ 0xBEA7);
        let mut depos = Vec::with_capacity(ntracks * (per_track + 2));
        for i in 0..ntracks {
            let x0 = rx + rng.uniform() * (xmax - rx);
            let y0 = ylo + (0.3 + 0.4 * rng.uniform()) * yspan;
            // small transverse slope so tracks are not axis-degenerate
            let dy = (rng.uniform() - 0.5) * 0.1 * yspan;
            let dx = (rng.uniform() - 0.5) * 0.05 * (xmax - rx);
            let t0 = rng.uniform() * spill;
            let mut track = TrackDepoSource {
                start: [x0, y0, zlo],
                end: [
                    (x0 + dx).clamp(rx, xmax),
                    (y0 + dy).clamp(ylo, yhi),
                    zhi,
                ],
                time: t0,
                step,
                loss: MipLoss::default(),
                seed: seed ^ (i as u64).wrapping_mul(GOLDEN),
                track_id: i as u64,
            };
            depos.extend(track.generate());
        }
        depos
    }

    fn witness(&self) -> ScenarioWitness {
        let (lo, hi) = self.det.transverse_extent();
        let zlen = self.napas as f64 * (hi - lo);
        let step_mm = self.step_for(zlen) / MM;
        ScenarioWitness {
            count: ((self.target / 2).max(1), 2 * self.target + 16),
            mean_charge: (MIP_E_PER_MM.0 * step_mm, MIP_E_PER_MM.1 * step_mm),
        }
    }
}

/// **cosmic-shower** — the paper's benchmark workload (§4.3.2: ~100k
/// depos from simulated cosmic rays) extended to a multi-APA row: each
/// APA tile receives its own cos²θ-distributed muon shower, sized so
/// the row totals roughly the configured target.  On a single APA this
/// reproduces the legacy `CosmicSource` workload bit for bit (tile 0
/// keeps the event seed).
pub struct CosmicShowerScenario {
    det: crate::geometry::Detector,
    target: usize,
}

impl CosmicShowerScenario {
    /// Cosmic workload sized to roughly `target` depos over the row.
    pub fn new(det: crate::geometry::Detector, target: usize) -> Self {
        Self {
            det,
            target: target.max(1),
        }
    }
}

impl Scenario for CosmicShowerScenario {
    fn name(&self) -> &str {
        "cosmic-shower"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        let napas = layout.napas();
        let per_apa = (self.target / napas).max(1);
        let mut depos = Vec::new();
        for k in 0..napas {
            // tile 0 keeps the event seed: a 1-APA cosmic-shower event
            // is bit-identical to CosmicSource::with_target_depos
            let tile_seed = seed.wrapping_add((k as u64).wrapping_mul(GOLDEN));
            let mut src = CosmicSource::with_target_depos(self.det.clone(), per_apa, tile_seed);
            let offset = k as f64 * layout.span();
            depos.extend(src.generate().into_iter().map(|mut d| {
                d.pos[2] += offset;
                d
            }));
        }
        depos
    }

    fn witness(&self) -> ScenarioWitness {
        // the cos²θ spread, early side exits, and whole-track
        // granularity at small targets make the count very broad (see
        // depo::cosmic tests); charge is MIP scale at 1 mm steps
        ScenarioWitness {
            count: ((self.target / 20).max(1), 10 * self.target + 2000),
            mean_charge: MIP_E_PER_MM,
        }
    }
}

/// **pileup-mix** — a beam spill overlaid with cosmic activity in the
/// same readout window (half the target each): the DUNE-era workload
/// where in-time pile-up makes per-event cost heavy-tailed.
pub struct PileupMixScenario {
    beam: BeamTrackScenario,
    cosmic: CosmicShowerScenario,
}

impl PileupMixScenario {
    /// Pile-up workload sized to roughly `target` depos over the row.
    pub fn new(det: crate::geometry::Detector, target: usize, napas: usize) -> Self {
        let half = (target / 2).max(1);
        Self {
            beam: BeamTrackScenario::new(det.clone(), half, napas),
            cosmic: CosmicShowerScenario::new(det, half),
        }
    }
}

impl Scenario for PileupMixScenario {
    fn name(&self) -> &str {
        "pileup-mix"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        // distinct sub-seeds so the overlay is not correlated with
        // either component run on its own
        let mut depos = self.beam.generate(layout, seed ^ 0x50_11);
        depos.extend(self.cosmic.generate(layout, seed ^ 0xC0_5A));
        depos
    }

    fn witness(&self) -> ScenarioWitness {
        let b = self.beam.witness();
        let c = self.cosmic.witness();
        ScenarioWitness {
            count: (b.count.0 + c.count.0, b.count.1 + c.count.1),
            mean_charge: (
                b.mean_charge.0.min(c.mean_charge.0),
                b.mean_charge.1.max(c.mean_charge.1),
            ),
        }
    }
}

/// **noise-only** — an empty depo set: the pedestal/calibration run.
/// Measures the fixed per-event floor (FT, noise generation, ADC)
/// every real event pays regardless of activity; run it with `--noise`
/// to produce pure-noise frames.
pub struct NoiseOnlyScenario;

impl Scenario for NoiseOnlyScenario {
    fn name(&self) -> &str {
        "noise-only"
    }

    fn generate(&self, _layout: &ApaLayout, _seed: u64) -> Vec<Depo> {
        Vec::new()
    }

    fn witness(&self) -> ScenarioWitness {
        ScenarioWitness {
            count: (0, 0),
            mean_charge: (0.0, 0.0),
        }
    }
}

/// **hotspot** — the whole target dropped as one Gaussian blob of
/// point depos (σ = 2 cm) inside APA 0: a neutrino-interaction-vertex
/// stand-in and the sharding worst case — one shard takes essentially
/// the entire event while the others idle, which is exactly the load
/// imbalance a per-APA work-stealing pool must absorb.
pub struct HotspotScenario {
    det: crate::geometry::Detector,
    target: usize,
}

/// Fixed charge of each hotspot point depo, electrons.
const HOTSPOT_CHARGE: f64 = 5_000.0;

impl HotspotScenario {
    /// Hotspot blob of exactly `target` point depos.
    pub fn new(det: crate::geometry::Detector, target: usize) -> Self {
        Self {
            det,
            target: target.max(1),
        }
    }
}

impl Scenario for HotspotScenario {
    fn name(&self) -> &str {
        "hotspot"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        let rx = self.det.response_plane_x;
        let xmax = usable_drift_x(&self.det);
        let center = [
            rx + 0.25 * (xmax - rx),
            0.0,
            layout.center_z(0),
        ];
        let sigma = 20.0 * MM;
        let readout = self.det.nticks as f64 * self.det.tick;
        let mut rng = Pcg32::seeded(seed ^ 0x407_5907);
        (0..self.target)
            .map(|i| {
                let pos = [
                    normal(&mut rng, center[0], sigma).clamp(rx, xmax),
                    normal(&mut rng, center[1], sigma),
                    normal(&mut rng, center[2], sigma),
                ];
                Depo::point(rng.uniform() * 0.05 * readout, pos, HOTSPOT_CHARGE, i as u64)
            })
            .collect()
    }

    fn witness(&self) -> ScenarioWitness {
        ScenarioWitness {
            count: (self.target, self.target),
            mean_charge: (HOTSPOT_CHARGE - 1.0, HOTSPOT_CHARGE + 1.0),
        }
    }
}

/// Knuth's product-of-uniforms Poisson sampler, clamped to `kmax` so
/// callers can quote a deterministic upper bound (the clamp is what
/// keeps the `full-detector` witness sound: an unbounded draw would
/// make its count ceiling probabilistic).
fn poisson_clamped(rng: &mut Pcg32, lambda: f64, kmax: usize) -> usize {
    if lambda <= 0.0 || kmax == 0 {
        return 0;
    }
    let limit = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.uniform();
        if p <= limit || k >= kmax {
            return k;
        }
        k += 1;
    }
}

/// **full-detector** — the production-shaped workload: a beam spill
/// crossing the whole APA row overlaid with K in-time cosmic showers,
/// where K ~ Poisson(`pileup_rate`) per readout window (clamped at a
/// deterministic ceiling so the witness bounds stay exact).  With the
/// `--preset full-detector` config this runs at ProtoDUNE-SP scale —
/// six [`protodune_sp`](crate::geometry::Detector::protodune_sp) faces
/// tiled along z — but the scenario itself scales to any detector and
/// APA count, like every other registry entry.
pub struct FullDetectorScenario {
    beam: BeamTrackScenario,
    cosmic: CosmicShowerScenario,
    rate: f64,
    kmax: usize,
}

impl FullDetectorScenario {
    /// Full-detector workload sized to roughly `target` depos over
    /// `napas` APAs at a mean of `pileup_rate` cosmic overlays per
    /// readout window (rate is clamped to [0, 64]).
    pub fn new(det: crate::geometry::Detector, target: usize, napas: usize, pileup_rate: f64) -> Self {
        let target = target.max(2);
        let rate = if pileup_rate.is_finite() {
            pileup_rate.clamp(0.0, 64.0)
        } else {
            0.0
        };
        // size each overlay so the *expected* total (beam + rate
        // overlays) lands near the target
        let overlay = ((target as f64 / 2.0) / rate.max(1.0)).ceil() as usize;
        Self {
            beam: BeamTrackScenario::new(det.clone(), (target / 2).max(1), napas),
            cosmic: CosmicShowerScenario::new(det, overlay.max(1)),
            rate,
            kmax: (4.0 * rate).ceil() as usize + 4,
        }
    }

    /// The deterministic ceiling on the per-window overlay count.
    pub fn max_overlays(&self) -> usize {
        self.kmax
    }
}

impl Scenario for FullDetectorScenario {
    fn name(&self) -> &str {
        "full-detector"
    }

    fn generate(&self, layout: &ApaLayout, seed: u64) -> Vec<Depo> {
        let mut depos = self.beam.generate(layout, seed ^ 0xFD_B0);
        let mut rng = Pcg32::seeded(seed ^ 0xFD_C0);
        let k = poisson_clamped(&mut rng, self.rate, self.kmax);
        for i in 0..k {
            // distinct, well-separated sub-seed per overlay so pileup
            // windows are mutually independent
            let sub = seed ^ 0xFD_CA ^ ((i as u64 + 1).wrapping_mul(GOLDEN));
            depos.extend(self.cosmic.generate(layout, sub));
        }
        depos
    }

    fn witness(&self) -> ScenarioWitness {
        let b = self.beam.witness();
        let c = self.cosmic.witness();
        ScenarioWitness {
            // K = 0 is possible, so only the beam floor is guaranteed;
            // the ceiling assumes the clamped worst case of kmax overlays
            count: (b.count.0, b.count.1 + self.kmax * c.count.1),
            mean_charge: (
                b.mean_charge.0.min(c.mean_charge.0),
                b.mean_charge.1.max(c.mean_charge.1),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depo::stats;
    use crate::geometry::Detector;

    fn layout(napas: usize) -> ApaLayout {
        ApaLayout::for_detector(&Detector::test_small(), napas)
    }

    #[test]
    fn beam_tracks_cross_every_apa() {
        let lay = layout(3);
        let scn = BeamTrackScenario::new(Detector::test_small(), 6000, 3);
        let depos = scn.generate(&lay, 11);
        scn.witness().check(&depos).unwrap();
        // every APA sees beam charge
        let mut per_apa = vec![0usize; 3];
        for d in &depos {
            if let Some(k) = lay.apa_of(d.pos[2]) {
                per_apa[k] += 1;
            }
        }
        assert!(per_apa.iter().all(|&n| n > 0), "{per_apa:?}");
    }

    #[test]
    fn beam_step_stretches_for_small_targets() {
        // target far below the row length in mm: one track, ~target depos
        let lay = layout(2);
        let scn = BeamTrackScenario::new(Detector::test_small(), 300, 2);
        let depos = scn.generate(&lay, 5);
        scn.witness().check(&depos).unwrap();
        assert!(depos.len() >= 150 && depos.len() <= 700, "{}", depos.len());
    }

    #[test]
    fn cosmic_single_apa_matches_legacy_source() {
        let det = Detector::test_small();
        let lay = layout(1);
        let scn = CosmicShowerScenario::new(det.clone(), 2000);
        let a = scn.generate(&lay, 9);
        let b = CosmicSource::with_target_depos(det, 2000, 9).generate();
        assert_eq!(a.len(), b.len());
        assert_eq!(stats(&a), stats(&b));
    }

    #[test]
    fn cosmic_tiles_every_apa() {
        let lay = layout(2);
        let scn = CosmicShowerScenario::new(Detector::test_small(), 8000);
        let depos = scn.generate(&lay, 3);
        scn.witness().check(&depos).unwrap();
        let in_apa1 = depos
            .iter()
            .filter(|d| lay.apa_of(d.pos[2]) == Some(1))
            .count();
        assert!(in_apa1 > 0);
    }

    #[test]
    fn hotspot_lands_on_one_apa() {
        let lay = layout(4);
        let scn = HotspotScenario::new(Detector::test_small(), 500);
        let depos = scn.generate(&lay, 21);
        scn.witness().check(&depos).unwrap();
        assert_eq!(depos.len(), 500);
        assert!(depos
            .iter()
            .all(|d| lay.apa_of(d.pos[2]) == Some(0)));
    }

    #[test]
    fn noise_only_is_empty() {
        let scn = NoiseOnlyScenario;
        assert!(scn.generate(&layout(2), 1).is_empty());
        scn.witness().check(&[]).unwrap();
    }

    #[test]
    fn generators_are_seed_deterministic() {
        let lay = layout(2);
        let det = Detector::test_small();
        let scns: Vec<Box<dyn Scenario>> = vec![
            Box::new(BeamTrackScenario::new(det.clone(), 1000, 2)),
            Box::new(CosmicShowerScenario::new(det.clone(), 1000)),
            Box::new(PileupMixScenario::new(det.clone(), 1000, 2)),
            Box::new(FullDetectorScenario::new(det.clone(), 1000, 2, 2.0)),
            Box::new(HotspotScenario::new(det, 200)),
        ];
        for scn in &scns {
            let a = scn.generate(&lay, 77);
            let b = scn.generate(&lay, 77);
            assert_eq!(a.len(), b.len(), "{} count drifted", scn.name());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x, y, "{} depo drifted", scn.name());
            }
            // a different seed moves the depos (hotspot keeps its total
            // charge fixed by construction, so compare full stats)
            let c = scn.generate(&lay, 78);
            assert_ne!(stats(&a), stats(&c), "{} ignores the seed", scn.name());
        }
    }

    #[test]
    fn poisson_sampler_tracks_the_rate_and_respects_the_clamp() {
        let mut rng = Pcg32::seeded(404);
        // rate 0 and clamp 0 are hard zeros
        assert_eq!(poisson_clamped(&mut rng, 0.0, 16), 0);
        assert_eq!(poisson_clamped(&mut rng, 3.0, 0), 0);
        // sample mean approaches lambda; every draw honors kmax
        let (lambda, kmax, n) = (2.0f64, 16usize, 4000usize);
        let mut sum = 0usize;
        for _ in 0..n {
            let k = poisson_clamped(&mut rng, lambda, kmax);
            assert!(k <= kmax);
            sum += k;
        }
        let mean = sum as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.15, "poisson mean {mean} vs {lambda}");
    }

    #[test]
    fn full_detector_overlays_beam_with_pileup() {
        let lay = layout(2);
        let scn = FullDetectorScenario::new(Detector::test_small(), 4000, 2, 2.0);
        // the witness ceiling must hold for every seed by construction;
        // spot-check a few, and check the beam floor is always there
        for seed in [1u64, 7, 12345, 20260731] {
            let depos = scn.generate(&lay, seed);
            scn.witness().check(&depos).unwrap_or_else(|e| {
                panic!("full-detector witness at seed {seed}: {e}");
            });
        }
        // rate 0 degenerates to the pure beam component
        let beamy = FullDetectorScenario::new(Detector::test_small(), 4000, 2, 0.0);
        let pure = BeamTrackScenario::new(Detector::test_small(), 2000, 2);
        let a = beamy.generate(&lay, 9);
        let b = pure.generate(&lay, 9 ^ 0xFD_B0);
        assert_eq!(a.len(), b.len());
        assert_eq!(stats(&a), stats(&b));
        // a busy rate really does add overlay charge on average
        let busy = FullDetectorScenario::new(Detector::test_small(), 4000, 2, 8.0);
        let total: usize = (0..8u64).map(|s| busy.generate(&lay, s).len()).sum();
        let beam_only: usize = (0..8u64).map(|s| beamy.generate(&lay, s).len()).sum();
        assert!(total > beam_only, "pileup added nothing: {total} vs {beam_only}");
    }

    #[test]
    fn pileup_mixes_both_components() {
        let lay = layout(2);
        let scn = PileupMixScenario::new(Detector::test_small(), 4000, 2);
        let depos = scn.generate(&lay, 13);
        scn.witness().check(&depos).unwrap();
        // beam depos cross the far APA; cosmics populate the near one
        let far = depos
            .iter()
            .filter(|d| lay.apa_of(d.pos[2]) == Some(1))
            .count();
        assert!(far > 0 && far < depos.len());
    }
}
