//! APA-sharded execution: fan one event's depos out to per-APA shards,
//! run each shard through its own [`SimSession`], and scatter-gather
//! the shard frames into one order-independent, digest-stable event
//! frame.
//!
//! Sharding is a pure execution-layer concern: every APA is an
//! identical copy of the base detector ([`ApaLayout`]), so a shard run
//! is just a normal single-detector session run over the depos that
//! landed in that APA's z window, translated into the APA's local
//! frame.  The determinism contract mirrors the throughput engine's:
//! shard `k` of event `e` derives every stochastic stage from
//! [`apa_seed`]`(e, k)` alone, so *which session or thread runs a
//! shard is unobservable in the output* — the serial loop and the
//! pooled executor produce bit-identical frames, and
//! [`ShardedReport::digest`] is the cheap witness
//! (`rust/tests/scenarios.rs` asserts the full guarantee).

use crate::backend::StageTimings;
use crate::config::SimConfig;
use crate::depo::Depo;
use crate::frame::Frame;
use crate::geometry::ApaLayout;
use crate::metrics::{StageTimer, Table};
use crate::rng::RandomPool;
use crate::session::{RunReport, SimSession};
use crate::throughput::frame_digest;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-shard seed: APA 0 keeps the event seed — so a 1-APA sharded run
/// is bit-identical to a plain [`SimSession`] run — and higher APAs
/// get a splitmix64-style mix of the event seed and the APA index.
pub fn apa_seed(event_seed: u64, apa: usize) -> u64 {
    if apa == 0 {
        return event_seed;
    }
    let mut z = (event_seed ^ 0xA9A5_0000_0000_A9A5)
        ^ (apa as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Split a global depo set into per-APA shards in APA-local
/// coordinates.  Depos outside the layout's z row are dropped — by
/// both execution paths identically, which is what keeps the digests
/// comparable.
pub fn shard_depos(depos: &[Depo], layout: &ApaLayout) -> Vec<Vec<Depo>> {
    let mut shards = vec![Vec::new(); layout.napas()];
    for d in depos {
        if let Some(k) = layout.apa_of(d.pos[2]) {
            let mut local = *d;
            local.pos[2] = layout.local_z(d.pos[2], k);
            shards[k].push(local);
        }
    }
    shards
}

/// How the shards of one event are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardExec {
    /// One session runs the shards sequentially in APA order — the
    /// "unsharded single-session" reference path.
    Serial,
    /// Up to `n` sessions race a shared shard queue (the same
    /// pull-based work-stealing discipline as
    /// [`run_pooled`](crate::dataflow::run_pooled)): an idle session
    /// takes the next APA index, so a hotspot shard never stalls the
    /// others.
    Pooled(usize),
}

/// Per-shard share of one sharded event.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// APA index.
    pub apa: usize,
    /// Depos that landed in this APA.
    pub depos: usize,
    /// Charge accumulated on this APA's grids (electrons).
    pub charge: f64,
    /// Wall-clock spent inside this shard's run [s].
    pub busy_s: f64,
    /// This shard's frame digest (0 when frames are disabled).
    pub digest: u64,
}

/// Everything one sharded event run reports.
pub struct ShardedReport {
    /// Backend row label of the shard sessions.
    pub label: String,
    /// Global input depo count (including dropped).
    pub depos: usize,
    /// Depos outside the layout's z row (dropped before sharding).
    pub dropped: usize,
    /// Per-shard accounting, APA order.
    pub shards: Vec<ShardStats>,
    /// Per-shard frames, APA order (`ident` = APA index; `None` when
    /// the sessions run frame-less).
    pub frames: Vec<Option<Frame>>,
    /// Stage timers merged over all shards.
    pub stages: StageTimer,
    /// Raster sampling/fluctuation split summed over all shards —
    /// the per-shard worker accounting behind the throughput engine's
    /// `raster.*` rows.
    pub raster: StageTimings,
    /// Reconstructed hits gathered over the shards in APA order, with
    /// channels re-indexed from APA-local to global (`local + apa ×
    /// nwires(plane)`) — empty unless the topology runs the reco chain.
    pub hits: Vec<crate::sigproc::Hit>,
}

impl ShardedReport {
    /// The scatter-gathered event frame: every shard's plane frames
    /// concatenated in APA order (U, V, W per APA), independent of the
    /// order the shards completed in.  `None` if any shard ran
    /// frame-less.
    pub fn event_frame(&self) -> Option<Frame> {
        let mut planes = Vec::new();
        for f in &self.frames {
            planes.extend(f.as_ref()?.planes.iter().cloned());
        }
        Some(Frame { planes, ident: 0 })
    }

    /// FNV fold over the APA-ordered shard digests — stable however
    /// the shards were scheduled, and therefore equal between the
    /// serial and pooled executors when (and only when) every shard
    /// frame is bit-identical.
    pub fn digest(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for s in &self.shards {
            h = (h ^ s.apa as u64).wrapping_mul(PRIME);
            h = (h ^ s.digest).wrapping_mul(PRIME);
        }
        h
    }

    /// Per-shard accounting table (the `wire-cell simulate` body for
    /// multi-APA runs).
    pub fn shard_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "per-APA shards — {} depos ({} dropped), backend {}",
                self.depos, self.dropped, self.label
            ),
            &["APA", "Depos", "Charge [e]", "Busy [s]", "Digest"],
        );
        for s in &self.shards {
            t.row(&[
                s.apa.to_string(),
                s.depos.to_string(),
                format!("{:.3e}", s.charge),
                format!("{:.3}", s.busy_s),
                format!("{:016x}", s.digest),
            ]);
        }
        t
    }
}

/// A multi-APA session: one [`SimSession`] per executor slot over a
/// shared [`ApaLayout`], driven by [`run_event`](Self::run_event).
///
/// All APAs are identical detectors, so the sessions are
/// interchangeable — a session is re-seeded with [`apa_seed`] before
/// each shard it runs, which is what lets the serial executor reuse
/// one session for every shard and the pooled executor hand shards to
/// whichever session goes idle, without the output depending on the
/// assignment.
pub struct ShardedSession {
    layout: ApaLayout,
    sessions: Vec<SimSession>,
    exec: ShardExec,
}

impl ShardedSession {
    /// Build a sharded session for `cfg` (`cfg.apas` APAs of
    /// `cfg.detector`).
    pub fn new(cfg: &SimConfig, exec: ShardExec) -> Result<Self> {
        Self::with_variate_pool(cfg, exec, None)
    }

    /// Like [`new`](Self::new), adopting a pre-generated variate-pool
    /// template (the throughput engine generates one per stream and
    /// every worker forks it).  Each internal session gets a private
    /// fork: shared bytes, private cursor.
    pub fn with_variate_pool(
        cfg: &SimConfig,
        exec: ShardExec,
        template: Option<&RandomPool>,
    ) -> Result<Self> {
        let det = cfg.detector().map_err(anyhow::Error::msg)?;
        let layout = ApaLayout::for_detector(&det, cfg.apas);
        let nsessions = match exec {
            ShardExec::Serial => 1,
            ShardExec::Pooled(n) => n.max(1).min(layout.napas()),
        };
        let owned;
        let template = match template {
            Some(t) => t,
            None => {
                owned = SimSession::variate_pool_for(cfg);
                owned.as_ref()
            }
        };
        let mut sessions = Vec::with_capacity(nsessions);
        for _ in 0..nsessions {
            sessions.push(
                SimSession::builder()
                    .config(cfg.clone())
                    .variate_pool(Arc::new(template.fork()))
                    .build()?,
            );
        }
        Ok(Self {
            layout,
            sessions,
            exec,
        })
    }

    /// The APA layout shards are split over.
    pub fn layout(&self) -> &ApaLayout {
        &self.layout
    }

    /// The configuration in force (shared by every shard session).
    pub fn config(&self) -> &SimConfig {
        self.sessions[0].config()
    }

    /// The per-APA base detector.
    pub fn detector(&self) -> &crate::geometry::Detector {
        self.sessions[0].detector()
    }

    /// Number of executor sessions (1 for serial, ≤ APAs for pooled).
    pub fn nsessions(&self) -> usize {
        self.sessions.len()
    }

    /// Shard a global depo set over the APAs, run every shard, and
    /// gather the results in APA order.
    pub fn run_event(&mut self, event_seed: u64, depos: &[Depo]) -> Result<ShardedReport> {
        let napas = self.layout.napas();
        let shards = shard_depos(depos, &self.layout);
        let dropped = depos.len() - shards.iter().map(Vec::len).sum::<usize>();
        let mut results: Vec<Option<(RunReport, f64)>> = (0..napas).map(|_| None).collect();
        match self.exec {
            ShardExec::Serial => {
                let session = &mut self.sessions[0];
                for (k, shard) in shards.iter().enumerate() {
                    session.reseed(apa_seed(event_seed, k));
                    let t0 = Instant::now();
                    let report = session.run(shard).with_context(|| format!("APA {k}"))?;
                    results[k] = Some((report, t0.elapsed().as_secs_f64()));
                }
            }
            ShardExec::Pooled(_) => {
                let work: Mutex<VecDeque<usize>> = Mutex::new((0..napas).collect());
                let done: Mutex<Vec<(usize, Result<RunReport>, f64)>> =
                    Mutex::new(Vec::with_capacity(napas));
                let shards = &shards;
                std::thread::scope(|scope| {
                    for session in self.sessions.iter_mut() {
                        let (work, done) = (&work, &done);
                        scope.spawn(move || loop {
                            // lock scope covers only the take, so the
                            // sessions overlap on the shard work
                            let next = work.lock().unwrap().pop_front();
                            let Some(k) = next else { break };
                            session.reseed(apa_seed(event_seed, k));
                            let t0 = Instant::now();
                            let r = session.run(&shards[k]);
                            done.lock()
                                .unwrap()
                                .push((k, r, t0.elapsed().as_secs_f64()));
                        });
                    }
                });
                for (k, r, busy_s) in done.into_inner().unwrap() {
                    results[k] = Some((r.with_context(|| format!("APA {k}"))?, busy_s));
                }
            }
        }
        // gather in APA order, whatever order the shards completed in
        let mut stages = StageTimer::new();
        let mut raster = StageTimings::default();
        let mut shard_stats = Vec::with_capacity(napas);
        let mut frames = Vec::with_capacity(napas);
        let mut hits = Vec::new();
        let mut label = String::new();
        // per-plane wire counts for the APA-local → global channel
        // re-indexing (every APA is an identical detector copy)
        let nwires = {
            let det = self.sessions[0].detector();
            [
                det.plane(crate::geometry::PlaneId::U).nwires,
                det.plane(crate::geometry::PlaneId::V).nwires,
                det.plane(crate::geometry::PlaneId::W).nwires,
            ]
        };
        for (k, slot) in results.into_iter().enumerate() {
            let (mut report, busy_s) = slot.expect("every shard ran");
            for mut h in report.hits.drain(..) {
                h.channel += k * nwires[h.plane as usize];
                hits.push(h);
            }
            stages.merge(&report.stages);
            raster.add(&report.raster_total());
            if label.is_empty() {
                label = report.label.clone();
            }
            let mut frame = report.frame.take();
            if let Some(f) = frame.as_mut() {
                f.ident = k as u64;
            }
            let digest = frame.as_ref().map(frame_digest).unwrap_or(0);
            shard_stats.push(ShardStats {
                apa: k,
                depos: report.depos,
                charge: report.planes.iter().map(|p| p.charge).sum(),
                busy_s,
                digest,
            });
            frames.push(frame);
        }
        Ok(ShardedReport {
            label,
            depos: depos.len(),
            dropped,
            shards: shard_stats,
            frames,
            stages,
            raster,
            hits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendChoice, FluctuationMode};
    use crate::geometry::Detector;
    use crate::units::*;

    fn cfg(apas: usize) -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.backend = BackendChoice::Serial;
        cfg.fluctuation = FluctuationMode::None;
        cfg.noise = false;
        cfg.apas = apas;
        cfg.pool_size = 1 << 14;
        cfg
    }

    /// A small two-APA depo set: one cluster per APA.
    fn two_apa_depos(layout: &ApaLayout) -> Vec<Depo> {
        let mut depos = Vec::new();
        for k in 0..layout.napas() {
            for i in 0..40 {
                depos.push(Depo::point(
                    i as f64 * US,
                    [40.0 * CM, 1.0 * CM, layout.center_z(k) + i as f64 * MM],
                    5_000.0,
                    (k * 100 + i) as u64,
                ));
            }
        }
        depos
    }

    #[test]
    fn apa_zero_keeps_the_event_seed() {
        assert_eq!(apa_seed(42, 0), 42);
        assert_ne!(apa_seed(42, 1), 42);
        assert_ne!(apa_seed(42, 1), apa_seed(42, 2));
        assert_ne!(apa_seed(42, 1), apa_seed(43, 1));
        // deterministic
        assert_eq!(apa_seed(7, 3), apa_seed(7, 3));
    }

    #[test]
    fn shard_depos_translates_and_drops() {
        let layout = ApaLayout::for_detector(&Detector::test_small(), 2);
        let (zlo, zhi) = layout.z_range();
        let depos = vec![
            Depo::point(0.0, [0.0, 0.0, zlo + 1.0 * MM], 1.0, 0),
            Depo::point(0.0, [0.0, 0.0, zlo + layout.span() + 1.0 * MM], 1.0, 1),
            Depo::point(0.0, [0.0, 0.0, zhi + 1.0 * MM], 1.0, 2), // outside
        ];
        let shards = shard_depos(&depos, &layout);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len(), 1);
        assert_eq!(shards[1].len(), 1);
        // both shards see the same *local* z
        assert!((shards[0][0].pos[2] - shards[1][0].pos[2]).abs() < 1e-9);
        assert_eq!(shards[0][0].id, 0);
        assert_eq!(shards[1][0].id, 1);
    }

    #[test]
    fn serial_and_pooled_executors_agree_bitwise() {
        let cfg = cfg(2);
        let mut serial = ShardedSession::new(&cfg, ShardExec::Serial).unwrap();
        let depos = two_apa_depos(serial.layout());
        let a = serial.run_event(cfg.seed, &depos).unwrap();
        let mut pooled = ShardedSession::new(&cfg, ShardExec::Pooled(2)).unwrap();
        assert_eq!(pooled.nsessions(), 2);
        let b = pooled.run_event(cfg.seed, &depos).unwrap();
        assert_eq!(a.digest(), b.digest());
        let (fa, fb) = (a.event_frame().unwrap(), b.event_frame().unwrap());
        assert_eq!(fa.planes.len(), 6); // U,V,W per APA
        for (pa, pb) in fa.planes.iter().zip(&fb.planes) {
            for (x, y) in pa.data.iter().zip(&pb.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn single_apa_matches_a_plain_session() {
        let cfg = cfg(1);
        let mut sharded = ShardedSession::new(&cfg, ShardExec::Serial).unwrap();
        let depos = two_apa_depos(sharded.layout());
        let report = sharded.run_event(cfg.seed, &depos).unwrap();
        let mut plain = SimSession::new(cfg.clone()).unwrap();
        let plain_report = plain.run(&depos).unwrap();
        let sharded_frame = report.event_frame().unwrap();
        let plain_frame = plain_report.frame.unwrap();
        assert_eq!(sharded_frame.planes.len(), plain_frame.planes.len());
        for (pa, pb) in sharded_frame.planes.iter().zip(&plain_frame.planes) {
            for (x, y) in pa.data.iter().zip(&pb.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn report_accounts_for_dropped_depos() {
        let cfg = cfg(2);
        let mut s = ShardedSession::new(&cfg, ShardExec::Serial).unwrap();
        let (_, zhi) = s.layout().z_range();
        let mut depos = two_apa_depos(s.layout());
        depos.push(Depo::point(0.0, [40.0 * CM, 0.0, zhi + 1.0 * M], 1.0, 999));
        let n = depos.len();
        let report = s.run_event(1, &depos).unwrap();
        assert_eq!(report.depos, n);
        assert_eq!(report.dropped, 1);
        assert_eq!(report.shards.iter().map(|x| x.depos).sum::<usize>(), n - 1);
        assert!(report.shard_table().render().contains("dropped"));
    }
}
