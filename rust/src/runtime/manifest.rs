//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::json::{parse, Value};
use std::collections::BTreeMap;

/// Grid constants baked into an artifact (must match the Rust grid).
#[derive(Clone, Debug, PartialEq)]
pub struct GridMeta {
    /// Wires on the plane.
    pub nwires: usize,
    /// Readout ticks.
    pub nticks: usize,
    /// Wire pitch (mm — matches `units::MM` base).
    pub pitch: f64,
    /// Sample period (ns base units).
    pub tick: f64,
    /// Impact positions per pitch.
    pub pitch_oversample: usize,
    /// Sub-ticks per tick.
    pub time_oversample: usize,
    /// Patch pitch-bin count (P).
    pub patch_p: usize,
    /// Patch time-bin count (T).
    pub patch_t: usize,
}

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// HLO text file name (relative to the artifacts dir).
    pub file: String,
    /// Input tensor shapes (same order as execution inputs).
    pub input_shapes: Vec<Vec<usize>>,
    /// Input dtypes ("float32" | "int32").
    pub input_dtypes: Vec<String>,
    /// Grid constants.
    pub grid: GridMeta,
    /// Strategy tag ("per-depo" | "batched" | "fused" | "ft").
    pub strategy: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Batch size the batched artifacts were lowered with.
    pub batch: usize,
    /// Pallas block size (depos per program instance).
    pub block: usize,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Self, String> {
        let doc = parse(text).map_err(|e| e.to_string())?;
        let batch = doc
            .get("batch")
            .and_then(Value::as_usize)
            .ok_or("manifest missing 'batch'")?;
        let block = doc
            .get("block")
            .and_then(Value::as_usize)
            .ok_or("manifest missing 'block'")?;
        let arts = doc
            .get("artifacts")
            .and_then(Value::as_object)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in arts {
            artifacts.insert(name.clone(), Self::parse_artifact(name, meta)?);
        }
        Ok(Self {
            batch,
            block,
            artifacts,
        })
    }

    fn parse_artifact(name: &str, meta: &Value) -> Result<ArtifactMeta, String> {
        let file = meta
            .get("file")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("artifact {name}: missing 'file'"))?
            .to_string();
        let inputs = meta
            .get("inputs")
            .and_then(Value::as_array)
            .ok_or_else(|| format!("artifact {name}: missing 'inputs'"))?;
        let mut input_shapes = Vec::new();
        let mut input_dtypes = Vec::new();
        for inp in inputs {
            let shape = inp
                .get("shape")
                .and_then(Value::as_array)
                .ok_or_else(|| format!("artifact {name}: input missing shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| "bad dim".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            input_shapes.push(shape);
            input_dtypes.push(
                inp.get("dtype")
                    .and_then(Value::as_str)
                    .unwrap_or("float32")
                    .to_string(),
            );
        }
        let g = meta
            .get("grid")
            .ok_or_else(|| format!("artifact {name}: missing 'grid'"))?;
        let gu = |k: &str| -> Result<usize, String> {
            g.get(k)
                .and_then(Value::as_usize)
                .ok_or_else(|| format!("artifact {name}: grid missing '{k}'"))
        };
        let gf = |k: &str| -> Result<f64, String> {
            g.get(k)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("artifact {name}: grid missing '{k}'"))
        };
        let grid = GridMeta {
            nwires: gu("nwires")?,
            nticks: gu("nticks")?,
            pitch: gf("pitch")?,
            tick: gf("tick")?,
            pitch_oversample: gu("pitch_oversample")?,
            time_oversample: gu("time_oversample")?,
            patch_p: gu("patch_p")?,
            patch_t: gu("patch_t")?,
        };
        let strategy = meta
            .get("strategy")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string();
        Ok(ArtifactMeta {
            file,
            input_shapes,
            input_dtypes,
            grid,
            strategy,
        })
    }
}

impl GridMeta {
    /// Build the matching Rust grid spec.
    pub fn grid_spec(&self) -> crate::raster::GridSpec {
        crate::raster::GridSpec::new(
            self.nwires,
            self.pitch,
            self.nticks,
            self.tick,
            self.pitch_oversample,
            self.time_oversample,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 256, "block": 32,
      "artifacts": {
        "raster_batch_small": {
          "file": "raster_batch_small.hlo.txt",
          "inputs": [
            {"shape": [256, 5], "dtype": "float32"},
            {"shape": [256, 2], "dtype": "int32"},
            {"shape": [256, 20, 20], "dtype": "float32"}
          ],
          "grid": {"nwires": 560, "nticks": 1024, "pitch": 3.0,
                   "tick": 500.0, "pitch_oversample": 5,
                   "time_oversample": 2, "patch_p": 20, "patch_t": 20},
          "strategy": "batched"
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.block, 32);
        let a = &m.artifacts["raster_batch_small"];
        assert_eq!(a.file, "raster_batch_small.hlo.txt");
        assert_eq!(a.input_shapes.len(), 3);
        assert_eq!(a.input_shapes[2], vec![256, 20, 20]);
        assert_eq!(a.input_dtypes[1], "int32");
        assert_eq!(a.grid.nwires, 560);
        assert_eq!(a.strategy, "batched");
    }

    #[test]
    fn grid_spec_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let spec = m.artifacts["raster_batch_small"].grid.grid_spec();
        assert_eq!(spec.coarse_shape(), (560, 1024));
        assert_eq!(spec.fine_shape(), (2800, 2048));
    }

    #[test]
    fn rejects_incomplete() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch":1,"block":1}"#).is_err());
        assert!(Manifest::parse(r#"{"batch":1,"block":1,"artifacts":{"x":{}}}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // When `make artifacts` has run, validate the real manifest.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.contains_key("raster_batch_small"));
            assert!(m.artifacts.contains_key("fused_pipeline_bench"));
        }
    }
}
