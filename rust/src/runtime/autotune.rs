//! Autotuned execution planning: a short measured sweep over the
//! host execution knobs that emits an [`ExecPlan`], cached in a
//! runtime manifest keyed by machine fingerprint + config digest.
//!
//! The knobs the sweep covers — backend (serial vs threads:ncpu),
//! strategy (batched vs fused), SIMD lanes (off vs auto) — are all
//! *throughput* knobs: every candidate produces bit-identical frames
//! (the fused/batched and lane parity contracts), so the plan can
//! never change physics, only wall clock.  That is what makes it safe
//! to apply a cached plan silently: `--autotune` runs the sweep once
//! per (machine, workload-config) pair, later runs reuse the stored
//! winner, and a digest mismatch (the workload changed) or a
//! fingerprint mismatch (the plan file moved machines) falls back to
//! the config's own knobs with a warning, never a panic.
//!
//! ```no_run
//! use wirecell::config::SimConfig;
//! use wirecell::runtime::autotune::{resolve, PlanSource, PlanStore};
//!
//! let mut cfg = SimConfig::default();
//! let store = PlanStore::at("artifacts/exec_plan.json");
//! let (plan, source) = resolve(&cfg, &store, /*tune=*/ true)?;
//! if source != PlanSource::Default {
//!     plan.apply(&mut cfg).map_err(anyhow::Error::msg)?;
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::config::SimConfig;
use crate::json::{self, Value};
use crate::scenario::Scenario as _;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Plan schema version; bump on incompatible field changes.  Stored
/// plans with another version are treated as stale (warn + fallback).
pub const PLAN_VERSION: usize = 1;

/// Cap on generated depos per probe event — the sweep measures knob
/// *ratios*, which stabilize well below production event sizes.
const PROBE_DEPOS: usize = 2_000;

/// Cap on the probe variate pool (pool fluctuation mode only needs to
/// cover the probe event).
const PROBE_POOL: usize = 1 << 16;

/// A resolved execution plan: the tuned knob settings plus the cache
/// key they were measured under.
///
/// Serialization is the repo's canonical JSON writer
/// ([`json::to_string_pretty`]): object keys come out of a `BTreeMap`
/// alphabetically sorted, so serialize → parse → re-serialize is
/// byte-stable — the property the golden-file test pins.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecPlan {
    /// Schema version ([`PLAN_VERSION`]).
    pub version: usize,
    /// Backend config string ("serial" | "threads:N" | "pjrt").
    pub backend: String,
    /// Strategy config string ("per-depo" | "batched" | "fused").
    pub strategy: String,
    /// Lane mode config string ("off" | "auto" | "x2" | "x4" | "x8").
    pub lanes: String,
    /// APA shard count the plan was measured at (recorded for audit;
    /// a workload fact, so [`apply`](Self::apply) never changes it).
    pub shards: usize,
    /// Throughput-engine worker pipelines (derived: fill the host with
    /// `workers × backend-threads ≈ ncpu`).
    pub workers: usize,
    /// Machine fingerprint the plan was measured on.
    pub fingerprint: String,
    /// Digest of the workload config (execution knobs excluded, so
    /// applying the plan does not invalidate its own cache key).
    pub config_digest: String,
}

impl ExecPlan {
    /// The no-tuning plan: a snapshot of the config's own knobs.
    pub fn default_for(cfg: &SimConfig) -> Self {
        Self {
            version: PLAN_VERSION,
            backend: cfg.backend.label(),
            strategy: cfg.strategy.as_str().to_string(),
            lanes: cfg.lanes.clone(),
            shards: cfg.apas,
            workers: cfg.workers,
            fingerprint: machine_fingerprint(),
            config_digest: config_digest(cfg),
        }
    }

    /// JSON form (keys alphabetical, see the type docs).
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("backend", Value::from(self.backend.as_str())),
            ("config_digest", Value::from(self.config_digest.as_str())),
            ("fingerprint", Value::from(self.fingerprint.as_str())),
            ("lanes", Value::from(self.lanes.as_str())),
            ("shards", Value::from(self.shards)),
            ("strategy", Value::from(self.strategy.as_str())),
            ("version", Value::from(self.version)),
            ("workers", Value::from(self.workers)),
        ])
    }

    /// Canonical serialized form (what the plan store writes).
    pub fn serialize(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Parse the canonical serialized form.
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| format!("exec plan: {e}"))?;
        Self::from_value(&v)
    }

    /// Build from a parsed JSON value.
    pub fn from_value(v: &Value) -> Result<Self, String> {
        let s = |k: &str| -> Result<String, String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(|x| x.to_string())
                .ok_or_else(|| format!("exec plan missing string key '{k}'"))
        };
        let n = |k: &str| -> Result<usize, String> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| format!("exec plan missing integer key '{k}'"))
        };
        Ok(Self {
            version: n("version")?,
            backend: s("backend")?,
            strategy: s("strategy")?,
            lanes: s("lanes")?,
            shards: n("shards")?,
            workers: n("workers")?,
            fingerprint: s("fingerprint")?,
            config_digest: s("config_digest")?,
        })
    }

    /// Overwrite the config's execution knobs with this plan's.  Only
    /// the four digest-excluded knobs change (backend, strategy,
    /// lanes, workers); the workload config is untouched, so frame
    /// digests are identical to a default-plan run by the parity
    /// contracts.
    pub fn apply(&self, cfg: &mut SimConfig) -> Result<(), String> {
        cfg.backend = self.backend.parse()?;
        cfg.strategy = self.strategy.parse()?;
        crate::simd::LaneMode::parse(&self.lanes).map_err(|e| format!("lanes: {e}"))?;
        cfg.lanes = self.lanes.clone();
        cfg.workers = self.workers.max(1);
        Ok(())
    }

    /// Whether this stored plan is valid for `cfg` on this machine.
    pub fn matches(&self, cfg: &SimConfig) -> bool {
        self.version == PLAN_VERSION
            && self.fingerprint == machine_fingerprint()
            && self.config_digest == config_digest(cfg)
    }
}

/// Where a resolved plan came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSource {
    /// Cache hit in the plan store.
    Cached,
    /// Freshly measured by [`autotune`] (and stored).
    Tuned,
    /// No cache entry and tuning off: the config's own knobs.
    Default,
}

/// Machine fingerprint the cache is keyed by: arch, OS and logical
/// CPU count — the facts that move a tuned winner.  Deliberately
/// coarse (no CPU model string: not portably available without a
/// dependency) and deterministic per host.
pub fn machine_fingerprint() -> String {
    let ncpu = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    format!(
        "{}-{}-c{ncpu}",
        std::env::consts::ARCH,
        std::env::consts::OS
    )
}

/// FNV-1a 64 digest of the *workload* config: the config JSON with
/// the execution knobs (backend, strategy, lanes, workers) removed,
/// so a plan keyed by this digest survives its own application.
pub fn config_digest(cfg: &SimConfig) -> String {
    let v = cfg.to_json();
    let mut obj = v.as_object().cloned().unwrap_or_default();
    for k in ["backend", "strategy", "lanes", "workers"] {
        obj.remove(k);
    }
    format!("{:016x}", fnv1a(json::to_string(&Value::Object(obj)).as_bytes()))
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn cache_key(cfg: &SimConfig) -> String {
    format!("{}|{}", machine_fingerprint(), config_digest(cfg))
}

/// On-disk plan cache: a JSON manifest
/// `{"plans": {"<fingerprint>|<digest>": {...plan...}}}`.
///
/// Every failure mode degrades to "no cached plan" with a warning on
/// stderr — a corrupt, truncated or foreign-machine manifest must
/// never take the simulation down.
pub struct PlanStore {
    path: PathBuf,
}

impl PlanStore {
    /// A store backed by `path` (need not exist yet).
    pub fn at(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into() }
    }

    /// The backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The manifest's plan table, or None (missing file) / empty-map
    /// fallback with a warning (corrupt file).
    fn load(&self) -> Option<BTreeMap<String, Value>> {
        let text = std::fs::read_to_string(&self.path).ok()?;
        match json::parse(&text) {
            Ok(v) => match v.get("plans").and_then(|p| p.as_object()) {
                Some(plans) => Some(plans.clone()),
                None => {
                    eprintln!(
                        "warning: plan manifest {} has no \"plans\" object; ignoring it",
                        self.path.display()
                    );
                    None
                }
            },
            Err(e) => {
                eprintln!(
                    "warning: plan manifest {} is corrupt ({e}); ignoring it",
                    self.path.display()
                );
                None
            }
        }
    }

    /// Cached plan for `cfg` on this machine, if a valid one exists.
    /// Stale entries (version or fingerprint mismatch) warn and miss.
    pub fn lookup(&self, cfg: &SimConfig) -> Option<ExecPlan> {
        let plans = self.load()?;
        let entry = plans.get(&cache_key(cfg))?;
        let plan = match ExecPlan::from_value(entry) {
            Ok(p) => p,
            Err(e) => {
                eprintln!(
                    "warning: cached plan in {} is malformed ({e}); re-deriving",
                    self.path.display()
                );
                return None;
            }
        };
        if !plan.matches(cfg) {
            eprintln!(
                "warning: cached plan in {} is stale (version/fingerprint/digest \
                 mismatch); re-deriving",
                self.path.display()
            );
            return None;
        }
        Some(plan)
    }

    /// Insert `plan` under its own cache key and rewrite the manifest.
    pub fn store(&self, plan: &ExecPlan) -> Result<()> {
        let mut plans = self.load().unwrap_or_default();
        plans.insert(
            format!("{}|{}", plan.fingerprint, plan.config_digest),
            plan.to_json(),
        );
        let doc = Value::object(vec![("plans", Value::Object(plans))]);
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(&self.path, json::to_string_pretty(&doc))
            .with_context(|| format!("writing {}", self.path.display()))?;
        Ok(())
    }
}

/// One sweep candidate: the knob triple a probe measures.
struct Candidate {
    backend: String,
    strategy: crate::config::Strategy,
    lanes: &'static str,
}

/// Run the measured sweep and return the winning plan.
///
/// Probes the host candidates — {serial, threads:ncpu} × {batched,
/// fused} × {lanes off, auto}, ≤ 8 probes — on a reduced copy of the
/// workload (`target_depos` capped at 2 000, pool at 2¹⁶), timing one
/// full default-topology event per probe, best of 2.  The PJRT
/// backend is never probed (device plans depend on compiled
/// artifacts, not host knobs): a pjrt config gets its own knobs back
/// unmeasured.
pub fn autotune(cfg: &SimConfig) -> Result<ExecPlan> {
    if cfg.backend == crate::config::BackendChoice::Pjrt {
        return Ok(ExecPlan::default_for(cfg));
    }
    let ncpu = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // Reduced probe workload: same scenario/detector/physics, capped
    // event size so the sweep stays sub-second per probe.
    let mut probe_base = cfg.clone();
    probe_base.target_depos = cfg.target_depos.min(PROBE_DEPOS);
    probe_base.pool_size = cfg.pool_size.min(PROBE_POOL);
    probe_base.topology.clear();

    let registry = crate::session::Registry::with_defaults();
    let scenario = registry.make_scenario(&probe_base)?;
    let detector = probe_base.detector().map_err(|e| anyhow!(e))?;
    let layout = crate::geometry::ApaLayout::for_detector(&detector, probe_base.apas);
    let depos = scenario.generate(&layout, probe_base.seed);

    let mut candidates = Vec::new();
    let mut backends = vec!["serial".to_string()];
    if ncpu > 1 {
        backends.push(format!("threads:{ncpu}"));
    }
    for backend in &backends {
        for strategy in [crate::config::Strategy::Batched, crate::config::Strategy::Fused] {
            for lanes in ["off", "auto"] {
                candidates.push(Candidate {
                    backend: backend.clone(),
                    strategy,
                    lanes,
                });
            }
        }
    }

    let mut best: Option<(f64, Candidate)> = None;
    for cand in candidates {
        let mut probe = probe_base.clone();
        probe.backend = cand.backend.parse().map_err(|e: String| anyhow!(e))?;
        probe.strategy = cand.strategy;
        probe.lanes = cand.lanes.to_string();
        let mut session = crate::session::SimSession::new(probe)?;
        // best of 2: the first run pays lazy costs (response spectra,
        // FFT plans) the second measures past
        let mut elapsed = f64::INFINITY;
        for _ in 0..2 {
            session.reseed(probe_base.seed);
            let t0 = Instant::now();
            session.run(&depos)?;
            elapsed = elapsed.min(t0.elapsed().as_secs_f64());
        }
        match &best {
            Some((t, _)) if *t <= elapsed => {}
            _ => best = Some((elapsed, cand)),
        }
    }
    let (_, winner) = best.ok_or_else(|| anyhow!("autotune: no candidates probed"))?;

    // Worker heuristic: fill the host — workers × backend-threads ≈
    // ncpu (measuring throughput workers directly would multiply the
    // sweep cost by the worker axis).
    let backend_threads = winner.backend.parse::<crate::config::BackendChoice>()
        .map(|b| b.threads())
        .unwrap_or(1);
    let workers = (ncpu / backend_threads.max(1)).max(1);

    Ok(ExecPlan {
        version: PLAN_VERSION,
        backend: winner.backend,
        strategy: winner.strategy.as_str().to_string(),
        lanes: winner.lanes.to_string(),
        shards: cfg.apas,
        workers,
        fingerprint: machine_fingerprint(),
        config_digest: config_digest(cfg),
    })
}

/// Resolve the plan for `cfg`: cache hit wins, otherwise a fresh
/// sweep when `tune` is set (stored for next time), otherwise the
/// config's own knobs.
pub fn resolve(cfg: &SimConfig, store: &PlanStore, tune: bool) -> Result<(ExecPlan, PlanSource)> {
    if let Some(plan) = store.lookup(cfg) {
        return Ok((plan, PlanSource::Cached));
    }
    if tune {
        let plan = autotune(cfg)?;
        store.store(&plan)?;
        return Ok((plan, PlanSource::Tuned));
    }
    Ok((ExecPlan::default_for(cfg), PlanSource::Default))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendChoice, FluctuationMode, Strategy};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wct_autotune_{}_{name}", std::process::id()))
    }

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.fluctuation = FluctuationMode::None;
        cfg.noise = false;
        cfg.target_depos = 300;
        cfg.pool_size = 1 << 14;
        cfg
    }

    #[test]
    fn plan_serialize_parse_reserialize_is_byte_stable() {
        let plan = ExecPlan::default_for(&SimConfig::default());
        let once = plan.serialize();
        let twice = ExecPlan::parse(&once).unwrap().serialize();
        assert_eq!(once, twice);
        // keys come out alphabetically (BTreeMap), pinning the layout
        let backend_at = once.find("\"backend\"").unwrap();
        let version_at = once.find("\"version\"").unwrap();
        assert!(backend_at < version_at);
    }

    #[test]
    fn digest_ignores_execution_knobs_but_not_workload() {
        let a = small_cfg();
        let mut b = a.clone();
        b.backend = BackendChoice::Threaded(4);
        b.strategy = Strategy::Fused;
        b.lanes = "x8".into();
        b.workers = 7;
        assert_eq!(config_digest(&a), config_digest(&b));
        let mut c = a.clone();
        c.target_depos = 301;
        assert_ne!(config_digest(&a), config_digest(&c));
    }

    #[test]
    fn apply_only_touches_the_digest_excluded_knobs() {
        let mut cfg = small_cfg();
        let before_digest = config_digest(&cfg);
        let plan = ExecPlan {
            version: PLAN_VERSION,
            backend: "threads:3".into(),
            strategy: "fused".into(),
            lanes: "x4".into(),
            shards: cfg.apas,
            workers: 2,
            fingerprint: machine_fingerprint(),
            config_digest: before_digest.clone(),
        };
        plan.apply(&mut cfg).unwrap();
        assert_eq!(cfg.backend, BackendChoice::Threaded(3));
        assert_eq!(cfg.strategy, Strategy::Fused);
        assert_eq!(cfg.lanes, "x4");
        assert_eq!(cfg.workers, 2);
        // the plan's own cache key survives its application
        assert_eq!(config_digest(&cfg), before_digest);
        assert!(plan.matches(&cfg));
        // and a bad lane string is rejected, not stored
        let mut bad = plan.clone();
        bad.lanes = "x16".into();
        assert!(bad.apply(&mut cfg).unwrap_err().contains("lanes"));
    }

    #[test]
    fn store_roundtrip_hit_and_miss() {
        let path = tmp("roundtrip.json");
        let _ = std::fs::remove_file(&path);
        let store = PlanStore::at(&path);
        let cfg = small_cfg();
        assert!(store.lookup(&cfg).is_none(), "fresh store must miss");
        let plan = ExecPlan::default_for(&cfg);
        store.store(&plan).unwrap();
        assert_eq!(store.lookup(&cfg), Some(plan));
        // a different workload misses without disturbing the entry
        let mut other = cfg.clone();
        other.target_depos = 999;
        assert!(store.lookup(&other).is_none());
        assert!(store.lookup(&cfg).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_manifest_warns_and_misses_instead_of_panicking() {
        let path = tmp("corrupt.json");
        std::fs::write(&path, "{not json at all").unwrap();
        let store = PlanStore::at(&path);
        assert!(store.lookup(&small_cfg()).is_none());
        // storing over a corrupt manifest heals it
        let plan = ExecPlan::default_for(&small_cfg());
        store.store(&plan).unwrap();
        assert_eq!(store.lookup(&small_cfg()), Some(plan));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_fingerprint_warns_and_misses() {
        let path = tmp("stale.json");
        let _ = std::fs::remove_file(&path);
        let cfg = small_cfg();
        let mut plan = ExecPlan::default_for(&cfg);
        plan.fingerprint = "mars-os9-c1".into();
        // plant it under the key lookup() will compute for cfg
        let store = PlanStore::at(&path);
        let mut plans = BTreeMap::new();
        plans.insert(cache_key(&cfg), plan.to_json());
        std::fs::write(
            &path,
            json::to_string_pretty(&Value::object(vec![("plans", Value::Object(plans))])),
        )
        .unwrap();
        assert!(store.lookup(&cfg).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resolve_tunes_once_then_hits_the_cache() {
        let path = tmp("resolve.json");
        let _ = std::fs::remove_file(&path);
        let store = PlanStore::at(&path);
        let cfg = small_cfg();
        // no cache, no tuning: the config's own knobs
        let (plan, source) = resolve(&cfg, &store, false).unwrap();
        assert_eq!(source, PlanSource::Default);
        assert_eq!(plan, ExecPlan::default_for(&cfg));
        // tune: measured winner lands in the store...
        let (tuned, source) = resolve(&cfg, &store, true).unwrap();
        assert_eq!(source, PlanSource::Tuned);
        assert!(tuned.matches(&cfg));
        // ...and the next resolve hits it byte-for-byte
        let (cached, source) = resolve(&cfg, &store, false).unwrap();
        assert_eq!(source, PlanSource::Cached);
        assert_eq!(cached, tuned);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pjrt_configs_are_never_probed() {
        let mut cfg = small_cfg();
        cfg.backend = BackendChoice::Pjrt;
        let plan = autotune(&cfg).unwrap();
        assert_eq!(plan.backend, "pjrt");
        assert_eq!(plan, ExecPlan::default_for(&cfg));
    }
}
