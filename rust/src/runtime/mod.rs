//! PJRT runtime: load AOT artifacts and execute them from Rust.
//!
//! This is the "device" of our reproduction (DESIGN.md §2): HLO text
//! produced once by `python/compile/aot.py` is compiled onto the PJRT
//! CPU client and executed from the L3 hot path.  Python never runs at
//! request time.
//!
//! The timing instrumentation deliberately mirrors the paper's
//! h→d / kernel / d→h decomposition (Table 2 annotates "incl. h->d" /
//! "incl. d->h"): literal construction is the host→device transfer
//! analog, `execute` the kernel, `to_literal_sync`+`to_vec` the
//! device→host read-back.

pub mod autotune;
mod manifest;

pub use manifest::{ArtifactMeta, GridMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

/// Cumulative transfer/execute timing (nanoseconds) and call counts.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    /// Host→device analog: literal construction time.
    pub h2d_ns: AtomicU64,
    /// Kernel execution time.
    pub exec_ns: AtomicU64,
    /// Device→host analog: literal fetch + conversion time.
    pub d2h_ns: AtomicU64,
    /// Number of `execute` dispatches.
    pub dispatches: AtomicU64,
}

impl RuntimeStats {
    /// Snapshot in seconds: (h2d, exec, d2h, dispatches).
    pub fn snapshot(&self) -> (f64, f64, f64, u64) {
        (
            self.h2d_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.d2h_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.dispatches.load(Ordering::Relaxed),
        )
    }

    /// Zero all counters.
    pub fn reset(&self) {
        self.h2d_ns.store(0, Ordering::Relaxed);
        self.exec_ns.store(0, Ordering::Relaxed);
        self.d2h_ns.store(0, Ordering::Relaxed);
        self.dispatches.store(0, Ordering::Relaxed);
    }
}

/// Typed tensor input for an artifact execution.
pub enum TensorInput<'a> {
    /// f32 tensor with shape.
    F32(&'a [f32], Vec<i64>),
    /// i32 tensor with shape.
    I32(&'a [i32], Vec<i64>),
}

/// The artifact runtime: PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Timing counters.
    pub stats: RuntimeStats,
}

// SAFETY: the PJRT CPU client (TfrtCpuClient) and its loaded
// executables are thread-safe by the PJRT C API contract; the only
// mutable Rust-side state is the executable cache, which is behind a
// Mutex.  This lets backends holding an `Arc<Runtime>` move across the
// dataflow engine's node threads.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            dir: dir.to_path_buf(),
            exes: Mutex::new(HashMap::new()),
            stats: RuntimeStats::default(),
        })
    }

    /// The manifest describing available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (for run reports).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.exes
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (so benchmarks exclude compile time).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute an artifact with the given inputs; returns the flattened
    /// f32 output of the (single-element) result tuple.
    pub fn execute_f32(&self, name: &str, inputs: &[TensorInput<'_>]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                let lit = match inp {
                    TensorInput::F32(data, shape) => {
                        xla::Literal::vec1(data).reshape(shape)?
                    }
                    TensorInput::I32(data, shape) => {
                        xla::Literal::vec1(data).reshape(shape)?
                    }
                };
                Ok(lit)
            })
            .collect::<Result<_>>()?;
        let t1 = Instant::now();

        let result = exe.execute::<xla::Literal>(&literals)?;
        let t2 = Instant::now();

        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        let t3 = Instant::now();

        self.stats
            .h2d_ns
            .fetch_add((t1 - t0).as_nanos() as u64, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add((t2 - t1).as_nanos() as u64, Ordering::Relaxed);
        self.stats
            .d2h_ns
            .fetch_add((t3 - t2).as_nanos() as u64, Ordering::Relaxed);
        self.stats.dispatches.fetch_add(1, Ordering::Relaxed);
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime integration tests (against real artifacts) live in
    // rust/tests/artifacts.rs; here we only test the pieces that do
    // not need a built artifacts/ tree.

    #[test]
    fn stats_snapshot_and_reset() {
        let s = RuntimeStats::default();
        s.h2d_ns.store(2_000_000_000, Ordering::Relaxed);
        s.dispatches.store(3, Ordering::Relaxed);
        let (h2d, exec, _, n) = s.snapshot();
        assert_eq!(h2d, 2.0);
        assert_eq!(exec, 0.0);
        assert_eq!(n, 3);
        s.reset();
        assert_eq!(s.snapshot().3, 0);
    }

    #[test]
    fn open_missing_dir_errors() {
        let r = Runtime::open(Path::new("/nonexistent/artifacts"));
        assert!(r.is_err());
    }
}
