//! Portable SIMD lanes: the crate-wide lane model behind the
//! vectorized hot loops (`raster::axis_masses`, the fused sweep's
//! weight products, the spectral engine's recombination and filter
//! multiplies).
//!
//! The design is `std::simd`-shaped but builds on stable Rust: a
//! "vector" is a fixed-size `[f64; W]` chunk processed in elementwise
//! lockstep — every lane performs exactly the scalar operation
//! sequence, so the vector paths are **bit-identical** to their scalar
//! oracles (the property `rust/tests/simd.rs` pins per scenario × lane
//! width × thread count), while the fixed trip counts let the
//! auto-vectorizer emit packed instructions.  Explicit intrinsics are
//! the re-scoped ROADMAP tail, not this layer.
//!
//! Three pieces live here:
//!
//! * [`Lanes`] — the typed lane-width vocabulary ([`Scalar`], [`X2`],
//!   [`X4`], [`X8`]).  Kernels are generic over `const W: usize`; the
//!   trait is the registry of supported widths (and their labels) that
//!   tests, the autotuner and the backend facts iterate.
//! * [`LaneMode`] — the config-string form (`off` / `auto` / `x2` /
//!   `x4` / `x8`) resolved to a runtime width.
//! * [`dispatch_lanes!`](crate::simd) — the runtime width → const
//!   width dispatcher kernels use to monomorphize their chunk loops.

/// Widest lane chunk any [`Lanes`] impl advertises.
pub const MAX_WIDTH: usize = 8;

/// Width `auto` resolves to: `f64x4` — one AVX2 register on x86-64,
/// a NEON register pair on aarch64, and a size the auto-vectorizer
/// handles well everywhere else.  A constant (not CPU-probed) so a
/// given config means the same thing on every host; the measured
/// choice between widths belongs to the autotuner.
pub const AUTO_WIDTH: usize = 4;

/// A lane width the vectorized kernels can run at.  Implementations
/// are zero-sized tags; kernels take `const W: usize` and the
/// [`dispatch_lanes!`](crate::simd) macro maps a runtime width onto
/// them, falling back to [`Scalar`] for any unsupported value.
pub trait Lanes: Copy + Default + Send + Sync + 'static {
    /// Number of f64 elements processed per lockstep chunk.
    const WIDTH: usize;
    /// Human label for reports and bench tables.
    const LABEL: &'static str;
}

/// One element per chunk: the scalar fallback (always available).
#[derive(Clone, Copy, Debug, Default)]
pub struct Scalar;

/// Two-wide f64 chunks (SSE2 / NEON register width).
#[derive(Clone, Copy, Debug, Default)]
pub struct X2;

/// Four-wide f64 chunks (AVX2 register width, the `auto` choice).
#[derive(Clone, Copy, Debug, Default)]
pub struct X4;

/// Eight-wide f64 chunks (AVX-512 register width).
#[derive(Clone, Copy, Debug, Default)]
pub struct X8;

impl Lanes for Scalar {
    const WIDTH: usize = 1;
    const LABEL: &'static str = "scalar";
}
impl Lanes for X2 {
    const WIDTH: usize = 2;
    const LABEL: &'static str = "f64x2";
}
impl Lanes for X4 {
    const WIDTH: usize = 4;
    const LABEL: &'static str = "f64x4";
}
impl Lanes for X8 {
    const WIDTH: usize = 8;
    const LABEL: &'static str = "f64x8";
}

/// Every width the dispatcher supports, ascending ([`Scalar`] first).
pub const SUPPORTED_WIDTHS: [usize; 4] = [Scalar::WIDTH, X2::WIDTH, X4::WIDTH, X8::WIDTH];

/// Label for a runtime width (unsupported widths read as scalar, which
/// is also how the dispatcher treats them).
pub fn label_for(width: usize) -> &'static str {
    match width {
        X2::WIDTH => X2::LABEL,
        X4::WIDTH => X4::LABEL,
        X8::WIDTH => X8::LABEL,
        _ => Scalar::LABEL,
    }
}

/// The configured lane mode: the `lanes` config key / `--lanes` CLI
/// option parsed into a policy, resolved to a width with
/// [`width`](LaneMode::width).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneMode {
    /// Scalar loops only (width 1).
    Off,
    /// The portable default width ([`AUTO_WIDTH`]).
    Auto,
    /// A fixed supported width (2, 4 or 8).
    Fixed(usize),
}

impl LaneMode {
    /// Parse the config-string form: `off`, `auto`, `x2`, `x4`, `x8`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "off" => Ok(Self::Off),
            "auto" => Ok(Self::Auto),
            "x2" => Ok(Self::Fixed(X2::WIDTH)),
            "x4" => Ok(Self::Fixed(X4::WIDTH)),
            "x8" => Ok(Self::Fixed(X8::WIDTH)),
            other => Err(format!(
                "unknown lane mode '{other}' (expected off | auto | x2 | x4 | x8)"
            )),
        }
    }

    /// The runtime lane width this mode resolves to.
    pub fn width(self) -> usize {
        match self {
            Self::Off => Scalar::WIDTH,
            Self::Auto => AUTO_WIDTH,
            Self::Fixed(w) => w,
        }
    }

    /// Canonical config-string form (what [`parse`](Self::parse) eats).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Auto => "auto",
            Self::Fixed(2) => "x2",
            Self::Fixed(8) => "x8",
            Self::Fixed(_) => "x4",
        }
    }
}

/// Monomorphize a lane-generic expression at a runtime width: binds the
/// const `$W` to 2, 4 or 8 when `$width` matches a supported vector
/// width, and to 1 (the scalar fallback) otherwise.
///
/// ```ignore
/// let w = params.lane_width;
/// dispatch_lanes!(w, W => axis_masses_lanes::<W>(center, sigma, bins, bin0, out));
/// ```
macro_rules! dispatch_lanes {
    ($width:expr, $W:ident => $body:expr) => {
        match $width {
            8 => {
                const $W: usize = 8;
                $body
            }
            4 => {
                const $W: usize = 4;
                $body
            }
            2 => {
                const $W: usize = 2;
                $body
            }
            _ => {
                const $W: usize = 1;
                $body
            }
        }
    };
}
pub(crate) use dispatch_lanes;

/// Elementwise `out[j] = k * xs[j]` over one lane chunk — the fused
/// sweep's weight product (`k = wp·norm`, `xs = wt` slice).  One
/// multiply per element, identical to the scalar loop's op, so the
/// chunked path is bit-identical.
#[inline(always)]
pub fn scale_chunk<const W: usize>(k: f64, xs: &[f64]) -> [f64; W] {
    let mut out = [0.0f64; W];
    for j in 0..W {
        out[j] = k * xs[j];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_strings_roundtrip() {
        for s in ["off", "auto", "x2", "x4", "x8"] {
            let m = LaneMode::parse(s).unwrap();
            assert_eq!(m.as_str(), s);
            assert_eq!(LaneMode::parse(m.as_str()).unwrap(), m);
        }
        assert!(LaneMode::parse("x16").is_err());
        assert!(LaneMode::parse("").is_err());
        assert!(LaneMode::parse("Auto").is_err());
    }

    #[test]
    fn widths_resolve() {
        assert_eq!(LaneMode::Off.width(), 1);
        assert_eq!(LaneMode::Auto.width(), AUTO_WIDTH);
        assert_eq!(LaneMode::parse("x2").unwrap().width(), 2);
        assert_eq!(LaneMode::parse("x8").unwrap().width(), 8);
        assert!(SUPPORTED_WIDTHS.contains(&AUTO_WIDTH));
        assert!(SUPPORTED_WIDTHS.iter().all(|&w| w <= MAX_WIDTH));
    }

    #[test]
    fn labels_name_the_widths() {
        assert_eq!(label_for(1), "scalar");
        assert_eq!(label_for(4), "f64x4");
        assert_eq!(label_for(3), "scalar"); // unsupported → scalar, like the dispatcher
    }

    #[test]
    fn dispatch_binds_the_const_width() {
        fn probe<const W: usize>() -> usize {
            W
        }
        for (input, expect) in [(1usize, 1usize), (2, 2), (4, 4), (8, 8), (0, 1), (3, 1), (16, 1)] {
            let got = dispatch_lanes!(input, W => probe::<W>());
            assert_eq!(got, expect, "width {input}");
        }
    }

    #[test]
    fn scale_chunk_matches_scalar_multiplies() {
        let xs = [0.25, -1.5, 3.0e-7, 42.0, 0.0, -0.0, 1.0, 2.0];
        let k = 0.12345;
        let out: [f64; 8] = scale_chunk(k, &xs);
        for j in 0..8 {
            assert_eq!(out[j].to_bits(), (k * xs[j]).to_bits());
        }
        let narrow: [f64; 2] = scale_chunk(k, &xs[..2]);
        assert_eq!(narrow[1].to_bits(), (k * xs[1]).to_bits());
    }
}
