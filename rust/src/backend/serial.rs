//! Serial CPU backend: the paper's original reference implementation.

use super::{ExecBackend, RasterOutput, StageTimings};
use crate::config::FluctuationMode;
use crate::kernel::{rasterize_fused_serial, FusedOutput};
use crate::raster::{fluctuate, patch_window, sample_2d, DepoView, Fluctuation, GridSpec, Patch, RasterParams};
use crate::rng::{Pcg32, RandomPool};
use crate::scatter::PlaneGrid;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// The ref-CPU / ref-CPU-noRNG rows: one thread, straightforward loop,
/// RNG either inline (expensive, the paper's Table-2 headline) or from
/// a pre-computed pool.
pub struct SerialBackend {
    params: RasterParams,
    mode: FluctuationMode,
    rng: Pcg32,
    pool: Option<Arc<RandomPool>>,
}

impl SerialBackend {
    /// Construct; `pool` is required for `FluctuationMode::Pool`.
    pub fn new(
        params: RasterParams,
        mode: FluctuationMode,
        seed: u64,
        pool: Option<Arc<RandomPool>>,
    ) -> Self {
        assert!(
            mode != FluctuationMode::Pool || pool.is_some(),
            "pool mode needs a RandomPool"
        );
        Self {
            params,
            mode,
            rng: Pcg32::seeded(seed),
            pool,
        }
    }
}

impl ExecBackend for SerialBackend {
    fn label(&self) -> String {
        match self.mode {
            FluctuationMode::Inline => "ref-CPU".into(),
            FluctuationMode::None => "ref-CPU-noRNG".into(),
            FluctuationMode::Pool => "ref-CPU-pool".into(),
        }
    }

    fn rasterize(&mut self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        let mut patches = Vec::with_capacity(views.len());
        let mut timings = StageTimings::default();
        for view in views {
            let Some(window) = patch_window(view, spec, &self.params) else {
                continue;
            };
            // Sub-step 1: 2D sampling.
            let t0 = Instant::now();
            let weights = sample_2d(view, spec, &self.params, window);
            let t1 = Instant::now();
            // Sub-step 2: fluctuation.
            let values = match self.mode {
                FluctuationMode::None => fluctuate(&weights, view.charge, &mut Fluctuation::None),
                FluctuationMode::Inline => fluctuate(
                    &weights,
                    view.charge,
                    &mut Fluctuation::InlineBinomial(&mut self.rng),
                ),
                FluctuationMode::Pool => fluctuate(
                    &weights,
                    view.charge,
                    &mut Fluctuation::PoolNormal(self.pool.as_ref().unwrap()),
                ),
            };
            let t2 = Instant::now();
            timings.sampling_s += (t1 - t0).as_secs_f64();
            timings.fluctuation_s += (t2 - t1).as_secs_f64();
            let (p0, np, t0_, nt) = window;
            patches.push(Patch {
                pbin0: p0,
                tbin0: t0_,
                np,
                nt,
                values,
            });
        }
        Ok(RasterOutput { patches, timings })
    }

    /// The reference rows are strictly single-threaded — spectral work
    /// (FT, noise) stays on the calling thread too, keeping the
    /// ref-CPU timings honest.
    fn spectral_policy(&self) -> crate::parallel::ExecPolicy {
        crate::parallel::ExecPolicy::Serial
    }

    /// Single-threaded but still lane-vectorized: the configured lane
    /// width applies to the axis fills and the fused sweep.
    fn lanes(&self) -> usize {
        self.params.lane_width.max(1)
    }

    /// The fused SoA kernel, single-threaded.  Uses the same RNG state
    /// (inline generator or variate-pool cursor) as
    /// [`rasterize`](ExecBackend::rasterize), so the produced grid is
    /// bit-identical to per-patch rasterize + serial scatter.
    fn rasterize_fused(
        &mut self,
        views: &[DepoView],
        spec: &GridSpec,
        grid: &mut PlaneGrid,
    ) -> Result<FusedOutput> {
        let out = match self.mode {
            FluctuationMode::None => {
                rasterize_fused_serial(views, spec, &self.params, &mut Fluctuation::None, grid)
            }
            FluctuationMode::Inline => rasterize_fused_serial(
                views,
                spec,
                &self.params,
                &mut Fluctuation::InlineBinomial(&mut self.rng),
                grid,
            ),
            FluctuationMode::Pool => rasterize_fused_serial(
                views,
                spec,
                &self.params,
                &mut Fluctuation::PoolNormal(self.pool.as_ref().unwrap()),
                grid,
            ),
        };
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::*;

    fn spec() -> GridSpec {
        GridSpec::new(100, 3.0 * MM, 256, 0.5 * US, 5, 2)
    }

    fn views(n: usize) -> Vec<DepoView> {
        (0..n)
            .map(|i| DepoView {
                pitch: (50.0 + i as f64) * MM,
                time: (20.0 + i as f64) * US,
                sigma_pitch: 1.5 * MM,
                sigma_time: 0.8 * US,
                charge: 5000.0,
            })
            .collect()
    }

    #[test]
    fn labels_match_paper_rows() {
        let p = RasterParams::default();
        assert_eq!(
            SerialBackend::new(p, FluctuationMode::Inline, 1, None).label(),
            "ref-CPU"
        );
        assert_eq!(
            SerialBackend::new(p, FluctuationMode::None, 1, None).label(),
            "ref-CPU-noRNG"
        );
    }

    #[test]
    fn norng_conserves_charge() {
        let mut b = SerialBackend::new(RasterParams::default(), FluctuationMode::None, 1, None);
        let out = b.rasterize(&views(10), &spec()).unwrap();
        assert_eq!(out.patches.len(), 10);
        for p in &out.patches {
            assert!((p.total() - 5000.0).abs() < 1.0, "{}", p.total());
        }
        assert!(out.timings.sampling_s > 0.0);
        // no RNG: fluctuation step is a trivial multiply
        assert!(out.timings.fluctuation_s < out.timings.sampling_s * 2.0);
    }

    #[test]
    fn inline_rng_dominates_timing() {
        // the Table-2 effect: inline exact binomial per bin is much
        // slower than the no-RNG fluctuation step
        let n = 200;
        let mut norng = SerialBackend::new(RasterParams::default(), FluctuationMode::None, 1, None);
        let mut inline = SerialBackend::new(RasterParams::default(), FluctuationMode::Inline, 1, None);
        let t_norng = norng.rasterize(&views(n), &spec()).unwrap().timings;
        let t_inline = inline.rasterize(&views(n), &spec()).unwrap().timings;
        assert!(
            t_inline.fluctuation_s > 5.0 * t_norng.fluctuation_s,
            "inline {:.6} vs norng {:.6}",
            t_inline.fluctuation_s,
            t_norng.fluctuation_s
        );
    }

    #[test]
    fn pool_mode_runs() {
        let pool = RandomPool::shared(3, 1 << 16);
        let mut b = SerialBackend::new(
            RasterParams::default(),
            FluctuationMode::Pool,
            1,
            Some(pool),
        );
        let out = b.rasterize(&views(20), &spec()).unwrap();
        assert_eq!(out.patches.len(), 20);
        let mean: f64 = out.patches.iter().map(|p| p.total()).sum::<f64>() / 20.0;
        assert!((mean - 5000.0).abs() < 100.0, "mean={mean}");
    }

    #[test]
    #[should_panic(expected = "pool mode needs a RandomPool")]
    fn pool_mode_without_pool_panics() {
        let _ = SerialBackend::new(RasterParams::default(), FluctuationMode::Pool, 1, None);
    }

    #[test]
    fn fused_equals_per_patch_plus_scatter() {
        // the strategy knob must not change the physics on one thread:
        // fused grid == rasterize + scatter_serial, bit for bit
        let vs = views(25);
        let s = spec();
        let pool = RandomPool::shared(3, 1 << 16);
        for mode in [FluctuationMode::None, FluctuationMode::Inline, FluctuationMode::Pool] {
            let mut a = SerialBackend::new(RasterParams::default(), mode, 7, Some(pool.clone()));
            pool.reset();
            let out = a.rasterize(&vs, &s).unwrap();
            let mut ref_grid = PlaneGrid::for_spec(&s);
            crate::scatter::scatter_serial(&mut ref_grid, &s, &out.patches);

            let mut b = SerialBackend::new(RasterParams::default(), mode, 7, Some(pool.clone()));
            pool.reset();
            let mut fused_grid = PlaneGrid::for_spec(&s);
            let fout = b.rasterize_fused(&vs, &s, &mut fused_grid).unwrap();
            assert_eq!(fout.depos, out.patches.len());
            assert_eq!(
                ref_grid.digest(),
                fused_grid.digest(),
                "mode {mode:?} broke fused bit parity"
            );
        }
    }

    #[test]
    fn off_grid_views_skipped() {
        let mut b = SerialBackend::new(RasterParams::default(), FluctuationMode::None, 1, None);
        let mut vs = views(3);
        vs[1].pitch = -10.0 * M; // far off grid
        let out = b.rasterize(&vs, &spec()).unwrap();
        assert_eq!(out.patches.len(), 2);
    }
}
