//! Host-parallel backend through the portable layer — the "Kokkos-OMP"
//! rows of Table 3.

use super::{ExecBackend, RasterOutput, StageTimings};
use crate::config::Strategy;
use crate::kernel::{rasterize_fused_threaded, FusedOutput};
use crate::parallel::{ExecPolicy, ThreadPool};
use crate::raster::{
    fluctuate, patch_window, sample_2d, DepoView, Fluctuation, GridSpec, Patch, RasterParams,
};
use crate::rng::RandomPool;
use crate::scatter::PlaneGrid;
use anyhow::Result;
use std::sync::Arc;
use std::sync::Mutex;
use std::time::Instant;

/// Rasterization over the portable `parallel` layer.
///
/// * `Strategy::PerDepo` reproduces the paper's first Kokkos port
///   (§4.3, Figure 3): each depo's patch is its own `parallel_for`
///   dispatch over the pool.  The work unit (~400 bins) is far below
///   the dispatch overhead, so *more threads run slower* — the paper's
///   Table-3 observation.
/// * `Strategy::Batched` is the Figure-4 fix on the host: one dispatch,
///   depos distributed across workers with per-worker RNG streams.
pub struct ThreadedBackend {
    params: RasterParams,
    strategy: Strategy,
    nthreads: usize,
    pool: Arc<ThreadPool>,
    rng_pool: Arc<RandomPool>,
    seed: u64,
}

impl ThreadedBackend {
    /// Construct over an existing thread pool.
    pub fn new(
        params: RasterParams,
        strategy: Strategy,
        nthreads: usize,
        pool: Arc<ThreadPool>,
        rng_pool: Arc<RandomPool>,
        seed: u64,
    ) -> Self {
        Self {
            params,
            strategy,
            nthreads: nthreads.max(1),
            pool,
            rng_pool,
            seed,
        }
    }
}

impl ExecBackend for ThreadedBackend {
    fn label(&self) -> String {
        let tag = match self.strategy {
            Strategy::PerDepo => "per-depo",
            Strategy::Batched => "batched",
            Strategy::Fused => "fused",
        };
        format!("Kokkos-OMP {} thread ({tag})", self.nthreads)
    }

    fn rasterize(&mut self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        match self.strategy {
            Strategy::PerDepo => self.rasterize_per_depo(views, spec),
            // the patch-returning API has no fused representation; Fused
            // falls back to the batched structure here, and the truly
            // fused path is `rasterize_fused` below
            Strategy::Batched | Strategy::Fused => self.rasterize_batched(views, spec),
        }
    }

    /// The FT and noise stages dispatch their spectral passes over this
    /// backend's pool width (bit-identical to serial — rows, columns
    /// and noise channels are independent work units).
    fn spectral_policy(&self) -> ExecPolicy {
        ExecPolicy::Threads(self.nthreads)
    }

    /// The configured lane width — threads and lanes compose (threads
    /// split depos/rows, lanes chunk each inner loop).
    fn lanes(&self) -> usize {
        self.params.lane_width.max(1)
    }

    /// The fused SoA kernel over the host pool: deterministic
    /// value-fill (pool variates indexed by flat bin offset) plus
    /// striped scatter — bit-identical output for any thread count,
    /// and to the serial fused kernel in pool mode.
    fn rasterize_fused(
        &mut self,
        views: &[DepoView],
        spec: &GridSpec,
        grid: &mut PlaneGrid,
    ) -> Result<FusedOutput> {
        Ok(rasterize_fused_threaded(
            views,
            spec,
            &self.params,
            &self.rng_pool,
            grid,
            &self.pool,
            self.nthreads,
        ))
    }
}

impl ThreadedBackend {
    /// Figure-3 structure: one pool dispatch per depo (per sub-step!),
    /// parallelizing over the patch's ~P rows — deliberately
    /// reproducing the tiny-work-unit dispatch pathology.
    fn rasterize_per_depo(&self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        let policy = ExecPolicy::Threads(self.nthreads);
        let mut patches = Vec::with_capacity(views.len());
        let mut timings = StageTimings::default();
        for view in views {
            let Some(window) = patch_window(view, spec, &self.params) else {
                continue;
            };
            let (p0, np, t0_, nt) = window;

            // Sub-step 1: 2D sampling, parallel over patch rows.
            let t0 = Instant::now();
            let weights = {
                let rows: Vec<Mutex<Vec<f64>>> = (0..np).map(|_| Mutex::new(Vec::new())).collect();
                crate::parallel::parallel_for(&self.pool, policy, np, 1, |range| {
                    for r in range {
                        // each row: the erf products for nt bins
                        let sub = sample_row(view, spec, &self.params, window, r);
                        *rows[r].lock().unwrap() = sub;
                    }
                });
                let mut w = Vec::with_capacity(np * nt);
                for row in rows {
                    w.extend(row.into_inner().unwrap());
                }
                // normalize across the whole patch (serial tail)
                let total: f64 = w.iter().sum();
                if total > 0.0 {
                    let inv = 1.0 / total;
                    w.iter_mut().for_each(|x| *x *= inv);
                }
                w
            };
            let t1 = Instant::now();

            // Sub-step 2: fluctuation from the pool, parallel over rows.
            let values = {
                let out: Vec<Mutex<Vec<f32>>> = (0..np).map(|_| Mutex::new(Vec::new())).collect();
                crate::parallel::parallel_for(&self.pool, policy, np, 1, |range| {
                    for r in range {
                        let row = &weights[r * nt..(r + 1) * nt];
                        let vals =
                            fluctuate(row, view.charge, &mut Fluctuation::PoolNormal(&self.rng_pool));
                        *out[r].lock().unwrap() = vals;
                    }
                });
                let mut v = Vec::with_capacity(np * nt);
                for row in out {
                    v.extend(row.into_inner().unwrap());
                }
                v
            };
            let t2 = Instant::now();

            timings.sampling_s += (t1 - t0).as_secs_f64();
            timings.fluctuation_s += (t2 - t1).as_secs_f64();
            patches.push(Patch {
                pbin0: p0,
                tbin0: t0_,
                np,
                nt,
                values,
            });
        }
        Ok(RasterOutput { patches, timings })
    }

    /// Figure-4 structure on the host: one dispatch, depos across
    /// workers.  Timing split is measured per-depo inside workers and
    /// accumulated (atomically) so the columns stay comparable.
    fn rasterize_batched(&self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        use std::sync::atomic::{AtomicU64, Ordering};
        let policy = ExecPolicy::Threads(self.nthreads);
        let slots: Vec<Mutex<Option<Patch>>> = (0..views.len()).map(|_| Mutex::new(None)).collect();
        let sampling_ns = AtomicU64::new(0);
        let fluct_ns = AtomicU64::new(0);
        let params = self.params;
        let rng_pool = &self.rng_pool;
        let seed = self.seed;
        crate::parallel::parallel_for(&self.pool, policy, views.len(), 64, |range| {
            let mut rng = crate::rng::Pcg32::seeded(seed).split(range.start as u64);
            let mut local_sample = 0u64;
            let mut local_fluct = 0u64;
            for i in range {
                let view = &views[i];
                let Some(window) = patch_window(view, spec, &params) else {
                    continue;
                };
                let t0 = Instant::now();
                let weights = sample_2d(view, spec, &params, window);
                let t1 = Instant::now();
                // batched host path keeps the pool-based fluctuation
                // (RNG factored out), falling back to inline if needed
                let values = if rng_pool.len() > 0 {
                    fluctuate(&weights, view.charge, &mut Fluctuation::PoolNormal(rng_pool))
                } else {
                    fluctuate(
                        &weights,
                        view.charge,
                        &mut Fluctuation::InlineBinomial(&mut rng),
                    )
                };
                let t2 = Instant::now();
                local_sample += (t1 - t0).as_nanos() as u64;
                local_fluct += (t2 - t1).as_nanos() as u64;
                let (p0, np, tb0, nt) = window;
                *slots[i].lock().unwrap() = Some(Patch {
                    pbin0: p0,
                    tbin0: tb0,
                    np,
                    nt,
                    values,
                });
            }
            sampling_ns.fetch_add(local_sample, Ordering::Relaxed);
            fluct_ns.fetch_add(local_fluct, Ordering::Relaxed);
        });
        let patches: Vec<Patch> = slots
            .into_iter()
            .filter_map(|s| s.into_inner().unwrap())
            .collect();
        // Per-worker times overlap in wall clock; report CPU-time sums
        // divided by concurrency to approximate wall time per column.
        let scale = 1.0 / self.nthreads as f64;
        Ok(RasterOutput {
            patches,
            timings: StageTimings {
                sampling_s: sampling_ns.load(Ordering::Relaxed) as f64 / 1e9 * scale,
                fluctuation_s: fluct_ns.load(Ordering::Relaxed) as f64 / 1e9 * scale,
                other_s: 0.0,
            },
        })
    }
}

/// One pitch row of un-normalized weights (helper for the per-depo
/// parallel decomposition).
fn sample_row(
    view: &DepoView,
    spec: &GridSpec,
    params: &RasterParams,
    window: (i64, usize, i64, usize),
    row: usize,
) -> Vec<f64> {
    let (p0, _np, t0, nt) = window;
    let sp = view.sigma_pitch.max(params.min_sigma_pitch);
    let st = view.sigma_time.max(params.min_sigma_time);
    let pb = spec.pitch_bins();
    let tb = spec.time_bins();
    let a = pb.edge(p0 + row as i64);
    let wp = crate::special::gauss_bin_integral(view.pitch, sp, a, a + pb.binsize());
    (0..nt)
        .map(|j| {
            let e = tb.edge(t0 + j as i64);
            wp * crate::special::gauss_bin_integral(view.time, st, e, e + tb.binsize())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::*;

    fn spec() -> GridSpec {
        GridSpec::new(100, 3.0 * MM, 256, 0.5 * US, 5, 2)
    }

    fn views(n: usize) -> Vec<DepoView> {
        (0..n)
            .map(|i| DepoView {
                pitch: (30.0 + (i % 200) as f64) * MM,
                time: (10.0 + (i % 100) as f64) * US,
                sigma_pitch: 1.5 * MM,
                sigma_time: 0.8 * US,
                charge: 5000.0,
            })
            .collect()
    }

    fn backend(strategy: Strategy, n: usize) -> ThreadedBackend {
        ThreadedBackend::new(
            RasterParams::default(),
            strategy,
            n,
            Arc::new(ThreadPool::new(n)),
            RandomPool::shared(1, 1 << 16),
            42,
        )
    }

    #[test]
    fn per_depo_matches_serial_weights() {
        // the parallel decomposition must produce the same patches as
        // the serial reference (modulo pool-RNG draws: use totals)
        let mut b = backend(Strategy::PerDepo, 2);
        let out = b.rasterize(&views(10), &spec()).unwrap();
        assert_eq!(out.patches.len(), 10);
        for p in &out.patches {
            assert!((p.total() - 5000.0).abs() < 300.0, "{}", p.total());
        }
    }

    #[test]
    fn batched_matches_expected_totals() {
        let mut b = backend(Strategy::Batched, 4);
        let out = b.rasterize(&views(50), &spec()).unwrap();
        assert_eq!(out.patches.len(), 50);
        let mean: f64 = out.patches.iter().map(|p| p.total()).sum::<f64>() / 50.0;
        assert!((mean - 5000.0).abs() < 50.0, "mean={mean}");
    }

    #[test]
    fn batched_patch_order_preserved() {
        let mut vs = views(5);
        vs[2].charge = 100.0;
        let mut b = backend(Strategy::Batched, 3);
        let out = b.rasterize(&vs, &spec()).unwrap();
        assert!((out.patches[2].total() - 100.0).abs() < 30.0);
    }

    #[test]
    fn label_encodes_threads_and_strategy() {
        assert_eq!(
            backend(Strategy::PerDepo, 4).label(),
            "Kokkos-OMP 4 thread (per-depo)"
        );
        assert_eq!(
            backend(Strategy::Batched, 2).label(),
            "Kokkos-OMP 2 thread (batched)"
        );
    }

    #[test]
    fn fused_label_and_bit_parity_across_thread_counts() {
        assert_eq!(
            backend(Strategy::Fused, 2).label(),
            "Kokkos-OMP 2 thread (fused)"
        );
        let vs = views(40);
        let s = spec();
        let mut digests = Vec::new();
        for threads in [1usize, 2, 4] {
            let rng_pool = RandomPool::shared(11, 1 << 16);
            let mut b = ThreadedBackend::new(
                RasterParams::default(),
                Strategy::Fused,
                threads,
                Arc::new(ThreadPool::new(threads)),
                rng_pool,
                42,
            );
            let mut grid = PlaneGrid::for_spec(&s);
            let out = b.rasterize_fused(&vs, &s, &mut grid).unwrap();
            assert_eq!(out.depos, 40);
            digests.push(grid.digest());
        }
        assert!(
            digests.windows(2).all(|w| w[0] == w[1]),
            "thread count changed the fused grid: {digests:?}"
        );
    }

    #[test]
    fn spectral_policy_reports_pool_width() {
        assert_eq!(
            backend(Strategy::Batched, 4).spectral_policy(),
            ExecPolicy::Threads(4)
        );
        let serial = crate::backend::SerialBackend::new(
            RasterParams::default(),
            crate::config::FluctuationMode::None,
            1,
            None,
        );
        assert_eq!(serial.spectral_policy(), ExecPolicy::Serial);
    }

    #[test]
    fn lanes_report_configured_width() {
        // default params are scalar; a lane-configured backend reports
        // its width, and zero clamps up to 1
        assert_eq!(backend(Strategy::Batched, 2).lanes(), 1);
        let mut params = RasterParams::default();
        params.lane_width = 4;
        let b = ThreadedBackend::new(
            params,
            Strategy::Fused,
            2,
            Arc::new(ThreadPool::new(2)),
            RandomPool::shared(1, 1 << 10),
            42,
        );
        assert_eq!(b.lanes(), 4);
        let mut params = RasterParams::default();
        params.lane_width = 0;
        let s = crate::backend::SerialBackend::new(
            params,
            crate::config::FluctuationMode::None,
            1,
            None,
        );
        assert_eq!(s.lanes(), 1);
    }

    #[test]
    fn timings_are_populated() {
        let mut b = backend(Strategy::PerDepo, 2);
        let t = b.rasterize(&views(20), &spec()).unwrap().timings;
        assert!(t.sampling_s > 0.0);
        assert!(t.fluctuation_s > 0.0);
    }
}
