//! Portable execution backends — the paper's evaluation axis.
//!
//! One user-level API ([`ExecBackend::rasterize`]) over three backends,
//! mirroring the Kokkos single-source / multi-backend model the paper
//! evaluates:
//!
//! | paper           | here                                         |
//! |-----------------|----------------------------------------------|
//! | ref-CPU         | [`SerialBackend`] + `Fluctuation::Inline`    |
//! | ref-CPU-noRNG   | [`SerialBackend`] + `Fluctuation::None`      |
//! | Kokkos-OMP (n)  | [`ThreadedBackend`] with n pool threads      |
//! | ref-CUDA / Kokkos-CUDA | [`PjrtBackend`] (AOT XLA artifacts)   |
//!
//! The *strategy* dimension (paper Figures 3 vs 4) is orthogonal:
//! `Strategy::PerDepo` dispatches one tiny kernel per depo (the paper's
//! initial port; dominated by dispatch/transfer overhead), while
//! `Strategy::Batched` processes depos in large blocks (the proposed
//! fix).  Both are implemented for every backend so the benches can
//! fill the full matrix.
//!
//! Stage timings are split into the paper's two columns —
//! "2D sampling" and "fluctuation" — at the same boundaries the paper
//! instruments (for the device path: sampling includes the h→d
//! transfer, fluctuation the d→h read-back; Table 2's annotations).

mod pjrt;
mod serial;
mod threaded;

pub use pjrt::PjrtBackend;
pub use serial::SerialBackend;
pub use threaded::ThreadedBackend;

use crate::raster::{DepoView, GridSpec, Patch};
use anyhow::Result;

/// Accumulated sub-step wall-clock, in seconds (Table 2/3 columns).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimings {
    /// "2D sampling" column (device: incl. h→d).
    pub sampling_s: f64,
    /// "Fluctuation" column (device: incl. d→h).
    pub fluctuation_s: f64,
    /// Anything not attributable to either (dispatch bookkeeping).
    pub other_s: f64,
}

impl StageTimings {
    /// Total rasterization time.
    pub fn total(&self) -> f64 {
        self.sampling_s + self.fluctuation_s + self.other_s
    }

    /// Accumulate another timing set.
    pub fn add(&mut self, other: &StageTimings) {
        self.sampling_s += other.sampling_s;
        self.fluctuation_s += other.fluctuation_s;
        self.other_s += other.other_s;
    }
}

/// Result of rasterizing a workload.
pub struct RasterOutput {
    /// The rasterized patches (order matches the input views).
    pub patches: Vec<Patch>,
    /// Stage timing split.
    pub timings: StageTimings,
}

/// The portable backend API (Kokkos analog): rasterize a batch of depo
/// views on whatever execution space the implementation owns.
/// `Send` so backends can ride dataflow-engine node threads.
pub trait ExecBackend: Send {
    /// Row label used in benchmark tables ("ref-CPU", "Kokkos-OMP 4", ...).
    fn label(&self) -> String;

    /// Rasterize the views into patches, timing the two sub-steps.
    fn rasterize(&mut self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_timings_accumulate() {
        let mut a = StageTimings {
            sampling_s: 1.0,
            fluctuation_s: 2.0,
            other_s: 0.5,
        };
        let b = StageTimings {
            sampling_s: 0.25,
            fluctuation_s: 0.25,
            other_s: 0.0,
        };
        a.add(&b);
        assert_eq!(a.total(), 4.0);
        assert_eq!(a.sampling_s, 1.25);
    }
}
