//! Portable execution backends — the paper's evaluation axis.
//!
//! One user-level API ([`ExecBackend::rasterize`]) over three backends,
//! mirroring the Kokkos single-source / multi-backend model the paper
//! evaluates:
//!
//! | paper           | here                                         |
//! |-----------------|----------------------------------------------|
//! | ref-CPU         | [`SerialBackend`] + `Fluctuation::Inline`    |
//! | ref-CPU-noRNG   | [`SerialBackend`] + `Fluctuation::None`      |
//! | Kokkos-OMP (n)  | [`ThreadedBackend`] with n pool threads      |
//! | ref-CUDA / Kokkos-CUDA | [`PjrtBackend`] (AOT XLA artifacts)   |
//!
//! The *strategy* dimension (paper Figures 3 vs 4) is orthogonal:
//! `Strategy::PerDepo` dispatches one tiny kernel per depo (the paper's
//! initial port; dominated by dispatch/transfer overhead),
//! `Strategy::Batched` processes depos in large blocks (the proposed
//! fix), and `Strategy::Fused` goes one step further — a single SoA
//! pass per event that rasterizes, fluctuates, and scatter-adds with
//! no intermediate patches ([`ExecBackend::rasterize_fused`], built on
//! [`crate::kernel`]).  All are implemented for every backend so the
//! benches can fill the full matrix.
//!
//! Stage timings are split into the paper's two columns —
//! "2D sampling" and "fluctuation" — at the same boundaries the paper
//! instruments (for the device path: sampling includes the h→d
//! transfer, fluctuation the d→h read-back; Table 2's annotations).

mod pjrt;
mod serial;
mod threaded;

pub use pjrt::PjrtBackend;
pub use serial::SerialBackend;
pub use threaded::ThreadedBackend;

use crate::kernel::FusedOutput;
use crate::parallel::ExecPolicy;
use crate::raster::{DepoView, GridSpec, Patch};
use crate::scatter::PlaneGrid;
use anyhow::Result;

/// Accumulated sub-step wall-clock, in seconds (Table 2/3 columns).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimings {
    /// "2D sampling" column (device: incl. h→d).
    pub sampling_s: f64,
    /// "Fluctuation" column (device: incl. d→h).
    pub fluctuation_s: f64,
    /// Anything not attributable to either (dispatch bookkeeping).
    pub other_s: f64,
}

impl StageTimings {
    /// Total rasterization time.
    pub fn total(&self) -> f64 {
        self.sampling_s + self.fluctuation_s + self.other_s
    }

    /// Accumulate another timing set.
    pub fn add(&mut self, other: &StageTimings) {
        self.sampling_s += other.sampling_s;
        self.fluctuation_s += other.fluctuation_s;
        self.other_s += other.other_s;
    }
}

/// Result of rasterizing a workload.
pub struct RasterOutput {
    /// The rasterized patches (order matches the input views).
    pub patches: Vec<Patch>,
    /// Stage timing split.
    pub timings: StageTimings,
}

/// The portable backend API (Kokkos analog): rasterize a batch of depo
/// views on whatever execution space the implementation owns.
/// `Send` so backends can ride dataflow-engine node threads.
pub trait ExecBackend: Send {
    /// Row label used in benchmark tables ("ref-CPU", "Kokkos-OMP 4", ...).
    fn label(&self) -> String;

    /// Rasterize the views into patches, timing the two sub-steps.
    ///
    /// # Example
    ///
    /// ```
    /// use wirecell::backend::{ExecBackend, SerialBackend};
    /// use wirecell::config::FluctuationMode;
    /// use wirecell::raster::{DepoView, GridSpec, RasterParams};
    /// use wirecell::units::{MM, US};
    ///
    /// let spec = GridSpec::new(40, 3.0 * MM, 64, 0.5 * US, 5, 2);
    /// let view = DepoView {
    ///     pitch: 60.0 * MM, time: 16.0 * US,
    ///     sigma_pitch: 1.5 * MM, sigma_time: 0.8 * US, charge: 5000.0,
    /// };
    /// let mut backend = SerialBackend::new(RasterParams::default(), FluctuationMode::None, 1, None);
    /// let out = backend.rasterize(&[view], &spec)?;
    /// assert_eq!(out.patches.len(), 1);
    /// assert!((out.patches[0].total() - 5000.0).abs() < 1.0);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    fn rasterize(&mut self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput>;

    /// Fused rasterize + scatter (`Strategy::Fused`): rasterize the
    /// views and accumulate them straight onto `grid`, without
    /// returning intermediate patches.
    ///
    /// The default implementation is the portable fallback — per-patch
    /// [`rasterize`](Self::rasterize) followed by a serial scatter-add —
    /// so every backend supports the fused strategy; the CPU backends
    /// override it with the truly fused SoA kernels in
    /// [`crate::kernel`], and the device backend with a streaming
    /// chunk scatter.
    fn rasterize_fused(
        &mut self,
        views: &[DepoView],
        spec: &GridSpec,
        grid: &mut PlaneGrid,
    ) -> Result<FusedOutput> {
        let out = self.rasterize(views, spec)?;
        crate::scatter::scatter_serial(grid, spec, &out.patches);
        Ok(FusedOutput {
            depos: out.patches.len(),
            bins: out.patches.iter().map(|p| p.size()).sum(),
            timings: out.timings,
        })
    }

    /// Host dispatch policy the spectral engine (the FT stage's 2-D
    /// row/column passes, batched noise synthesis) should use on this
    /// backend — the backend owns the "how parallel is the host" fact,
    /// so the session stages ask it instead of re-deriving from config.
    /// Serial by default; the threaded backend reports its pool width.
    /// The spectral passes are bit-identical for every policy, so this
    /// is purely a throughput knob.
    fn spectral_policy(&self) -> ExecPolicy {
        ExecPolicy::Serial
    }

    /// Host SIMD lane width the backend's raster hot loops run at
    /// (1 = scalar).  Like [`spectral_policy`](Self::spectral_policy)
    /// this is a fact the backend owns: the CPU backends report their
    /// configured `RasterParams::lane_width`, while the device backend
    /// reports 1 — its hot loops run on the accelerator, so host lanes
    /// don't apply.  The lane paths are bit-identical to scalar, so
    /// this is purely a throughput knob.
    fn lanes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_timings_accumulate() {
        let mut a = StageTimings {
            sampling_s: 1.0,
            fluctuation_s: 2.0,
            other_s: 0.5,
        };
        let b = StageTimings {
            sampling_s: 0.25,
            fluctuation_s: 0.25,
            other_s: 0.0,
        };
        a.add(&b);
        assert_eq!(a.total(), 4.0);
        assert_eq!(a.sampling_s, 1.25);
    }
}
