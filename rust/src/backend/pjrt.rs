//! Device backend: AOT XLA artifacts on the PJRT client — the
//! "ref-CUDA" / "Kokkos-CUDA" analog (DESIGN.md §2).

use super::{ExecBackend, RasterOutput, StageTimings};
use crate::config::Strategy;
use crate::kernel::FusedOutput;
use crate::raster::{patch_window, DepoView, GridSpec, Patch, RasterParams};
use crate::rng::RandomPool;
use crate::runtime::{Runtime, TensorInput};
use crate::scatter::PlaneGrid;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

/// Rasterization through PJRT-executed artifacts.
///
/// * `Strategy::PerDepo` (paper Figure 3): two tiny `execute` calls per
///   depo — `raster_sample_single_*` (the 2D-sampling kernel, timing
///   includes the parameter upload ≙ h→d) then `fluct_single_*` (the
///   fluctuation kernel, timing includes the patch download ≙ d→h).
///   Exactly the structure whose overhead Table 2 quantifies.
/// * `Strategy::Batched` (Figure 4): one `raster_batch_*` execute per
///   `batch` depos; transfers amortize and the dispatch count drops by
///   ~256×.
///
/// Patches are fixed `P×T` windows centered on each depo (the artifact
/// shapes are static); the Rust scatter stage clips overhang exactly as
/// it does for variable windows.
pub struct PjrtBackend {
    runtime: Arc<Runtime>,
    grid_name: String,
    strategy: Strategy,
    params: RasterParams,
    pool: Arc<RandomPool>,
    /// Extra per-dispatch synchronization work (seconds) emulating the
    /// portability layer's bookkeeping — 0.0 for the "raw CUDA" rows,
    /// >0 for "Kokkos-CUDA" rows (see `with_abstraction_overhead`).
    sync_overhead_s: f64,
    label: String,
}

impl PjrtBackend {
    /// New device backend for the artifact set `grid_name`
    /// ("small" | "bench").
    pub fn new(
        runtime: Arc<Runtime>,
        grid_name: &str,
        strategy: Strategy,
        params: RasterParams,
        pool: Arc<RandomPool>,
    ) -> Result<Self> {
        let be = Self {
            runtime,
            grid_name: grid_name.to_string(),
            strategy,
            params,
            pool,
            sync_overhead_s: 0.0,
            label: format!("ref-accel ({})", strategy_tag(strategy)),
        };
        be.check_artifacts()?;
        Ok(be)
    }

    /// Model the Kokkos abstraction overhead: the paper measured
    /// Kokkos-CUDA ≈ 2× ref-CUDA, attributing it to slower
    /// `parallel_reduce` kernels and extra device/stream
    /// synchronizations between kernels (§4.3.2).  This adds a busy
    /// sync of `overhead_us` per dispatch to reproduce that regime.
    pub fn with_abstraction_overhead(mut self, overhead_us: f64) -> Self {
        self.sync_overhead_s = overhead_us * 1e-6;
        self.label = format!("Kokkos-accel ({})", strategy_tag(self.strategy));
        self
    }

    fn check_artifacts(&self) -> Result<()> {
        for name in [
            format!("raster_sample_single_{}", self.grid_name),
            format!("fluct_single_{}", self.grid_name),
            format!("raster_batch_{}", self.grid_name),
        ] {
            if !self.runtime.manifest().artifacts.contains_key(&name) {
                return Err(anyhow!("artifact '{name}' missing — run `make artifacts`"));
            }
        }
        Ok(())
    }

    /// Patch dims from the manifest (P, T).
    fn patch_shape(&self) -> (usize, usize) {
        let meta = &self.runtime.manifest().artifacts[&format!("raster_batch_{}", self.grid_name)];
        (meta.grid.patch_p, meta.grid.patch_t)
    }

    /// Compute the fixed-size window origin for a view: centered on the
    /// depo, ignoring the ±nσ extent (static shapes).
    fn fixed_window(&self, view: &DepoView, spec: &GridSpec, p: usize, t: usize) -> (i32, i32) {
        let pb = spec.pitch_bins().bin_unclamped(view.pitch) - (p as i64) / 2;
        let tb = spec.time_bins().bin_unclamped(view.time) - (t as i64) / 2;
        (pb as i32, tb as i32)
    }

    fn busy_sync(&self) {
        if self.sync_overhead_s > 0.0 {
            let t0 = Instant::now();
            while t0.elapsed().as_secs_f64() < self.sync_overhead_s {
                std::hint::spin_loop();
            }
        }
    }

    /// Marshal one chunk of views into the `raster_batch_*` input
    /// vectors.  Shared by the batched and fused paths so the
    /// parameter-vector layout (and the sigma floors baked into it)
    /// can never diverge between them.
    fn marshal_chunk(
        &self,
        chunk: &[&DepoView],
        spec: &GridSpec,
        batch: usize,
        p: usize,
        t: usize,
    ) -> ChunkInputs {
        let mut params = vec![0f32; batch * 5];
        let mut windows = vec![0i32; batch * 2];
        let mut origins = Vec::with_capacity(chunk.len());
        for (i, view) in chunk.iter().enumerate() {
            let (pb, tb) = self.fixed_window(view, spec, p, t);
            params[i * 5] = view.pitch as f32;
            params[i * 5 + 1] = view.time as f32;
            params[i * 5 + 2] = view.sigma_pitch.max(self.params.min_sigma_pitch) as f32;
            params[i * 5 + 3] = view.sigma_time.max(self.params.min_sigma_time) as f32;
            params[i * 5 + 4] = view.charge as f32;
            windows[i * 2] = pb;
            windows[i * 2 + 1] = tb;
            origins.push((pb, tb));
        }
        let mut normals = vec![0f32; batch * p * t];
        self.pool.fill_normals(&mut normals);
        ChunkInputs {
            params,
            windows,
            origins,
            normals,
        }
    }

    fn rasterize_per_depo(&self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        let (p, t) = self.patch_shape();
        let sample_name = format!("raster_sample_single_{}", self.grid_name);
        let fluct_name = format!("fluct_single_{}", self.grid_name);
        self.runtime.warmup(&sample_name)?;
        self.runtime.warmup(&fluct_name)?;
        let mut patches = Vec::with_capacity(views.len());
        let mut timings = StageTimings::default();
        for view in views {
            if patch_window(view, spec, &self.params).is_none() {
                continue; // off-grid, same skip rule as the CPU paths
            }
            let (pb, tb) = self.fixed_window(view, spec, p, t);
            let params: [f32; 5] = [
                view.pitch as f32,
                view.time as f32,
                view.sigma_pitch.max(self.params.min_sigma_pitch) as f32,
                view.sigma_time.max(self.params.min_sigma_time) as f32,
                view.charge as f32,
            ];
            let windows: [i32; 2] = [pb, tb];

            // Kernel 1: 2D sampling (upload params = h→d analog).
            let t0 = Instant::now();
            let vpatch = self.runtime.execute_f32(
                &sample_name,
                &[
                    TensorInput::F32(&params, vec![1, 5]),
                    TensorInput::I32(&windows, vec![1, 2]),
                ],
            )?;
            self.busy_sync();
            let t1 = Instant::now();

            // Kernel 2: fluctuation (download patch = d→h analog).
            let mut cursor = self.pool.claim(p * t);
            let normals: Vec<f32> = (0..p * t).map(|_| cursor.next_normal(&self.pool)).collect();
            let charge = [view.charge as f32];
            let values = self.runtime.execute_f32(
                &fluct_name,
                &[
                    TensorInput::F32(&vpatch, vec![1, p as i64, t as i64]),
                    TensorInput::F32(&charge, vec![1]),
                    TensorInput::F32(&normals, vec![1, p as i64, t as i64]),
                ],
            )?;
            self.busy_sync();
            let t2 = Instant::now();

            timings.sampling_s += (t1 - t0).as_secs_f64();
            timings.fluctuation_s += (t2 - t1).as_secs_f64();
            patches.push(Patch {
                pbin0: pb as i64,
                tbin0: tb as i64,
                np: p,
                nt: t,
                values,
            });
        }
        Ok(RasterOutput { patches, timings })
    }

    fn rasterize_batched(&self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        let (p, t) = self.patch_shape();
        let batch = self.runtime.manifest().batch;
        let name = format!("raster_batch_{}", self.grid_name);
        self.runtime.warmup(&name)?;
        let mut patches = Vec::with_capacity(views.len());
        let mut timings = StageTimings::default();
        // Keep only on-grid views (same rule as CPU paths), then chunk.
        let kept: Vec<&DepoView> = views
            .iter()
            .filter(|v| patch_window(v, spec, &self.params).is_some())
            .collect();
        for chunk in kept.chunks(batch) {
            let inputs = self.marshal_chunk(chunk, spec, batch, p, t);
            let origins = &inputs.origins;

            let t0 = Instant::now();
            let out = self.runtime.execute_f32(
                &name,
                &[
                    TensorInput::F32(&inputs.params, vec![batch as i64, 5]),
                    TensorInput::I32(&inputs.windows, vec![batch as i64, 2]),
                    TensorInput::F32(&inputs.normals, vec![batch as i64, p as i64, t as i64]),
                ],
            )?;
            self.busy_sync();
            let t1 = Instant::now();
            // one fused kernel: attribute to the two columns by the
            // paper's boundary — upload+sampling vs compute+download —
            // using the runtime's h2d/d2h split (approximation noted in
            // EXPERIMENTS.md)
            let dt = (t1 - t0).as_secs_f64();
            timings.sampling_s += dt * 0.5;
            timings.fluctuation_s += dt * 0.5;

            for (i, (pb, tb)) in origins.iter().enumerate() {
                patches.push(Patch {
                    pbin0: *pb as i64,
                    tbin0: *tb as i64,
                    np: p,
                    nt: t,
                    values: out[i * p * t..(i + 1) * p * t].to_vec(),
                });
            }
        }
        Ok(RasterOutput { patches, timings })
    }
}

/// One chunk's marshalled `raster_batch_*` inputs (see
/// [`PjrtBackend::marshal_chunk`]).
struct ChunkInputs {
    /// Per-depo parameter vectors, `[batch × 5]` row-major.
    params: Vec<f32>,
    /// Per-depo window origins for the device, `[batch × 2]`.
    windows: Vec<i32>,
    /// The same origins, host-side, for the scatter stage.
    origins: Vec<(i32, i32)>,
    /// Pool normals, `[batch × P × T]`.
    normals: Vec<f32>,
}

fn strategy_tag(s: Strategy) -> &'static str {
    match s {
        Strategy::PerDepo => "per-depo",
        Strategy::Batched => "batched",
        Strategy::Fused => "fused",
    }
}

impl ExecBackend for PjrtBackend {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn rasterize(&mut self, views: &[DepoView], spec: &GridSpec) -> Result<RasterOutput> {
        match self.strategy {
            Strategy::PerDepo => self.rasterize_per_depo(views, spec),
            // the patch-returning API has no fused representation; the
            // fused path is `rasterize_fused` below
            Strategy::Batched | Strategy::Fused => self.rasterize_batched(views, spec),
        }
    }

    /// Fused device strategy: the batched param-vector export
    /// (one `raster_batch_*` execute per chunk), with each returned
    /// device buffer scatter-added straight onto the grid — no `Patch`
    /// vector is ever materialized, so host memory stays O(batch)
    /// instead of O(event).
    fn rasterize_fused(
        &mut self,
        views: &[DepoView],
        spec: &GridSpec,
        grid: &mut PlaneGrid,
    ) -> Result<FusedOutput> {
        let (p, t) = self.patch_shape();
        let batch = self.runtime.manifest().batch;
        let name = format!("raster_batch_{}", self.grid_name);
        self.runtime.warmup(&name)?;
        let kept: Vec<&DepoView> = views
            .iter()
            .filter(|v| patch_window(v, spec, &self.params).is_some())
            .collect();
        let nticks = grid.nticks;
        let mut timings = StageTimings::default();
        let mut bins = 0usize;
        for chunk in kept.chunks(batch) {
            let inputs = self.marshal_chunk(chunk, spec, batch, p, t);

            let t0 = Instant::now();
            let out = self.runtime.execute_f32(
                &name,
                &[
                    TensorInput::F32(&inputs.params, vec![batch as i64, 5]),
                    TensorInput::I32(&inputs.windows, vec![batch as i64, 2]),
                    TensorInput::F32(&inputs.normals, vec![batch as i64, p as i64, t as i64]),
                ],
            )?;
            self.busy_sync();
            let dt = t0.elapsed().as_secs_f64();
            timings.sampling_s += dt * 0.5;
            timings.fluctuation_s += dt * 0.5;

            // stream the device buffer straight onto the grid
            for (i, (pb, tb)) in inputs.origins.iter().enumerate() {
                let vals = &out[i * p * t..(i + 1) * p * t];
                for pp in 0..p {
                    let Some(w) = spec.wire_of(*pb as i64 + pp as i64) else {
                        continue;
                    };
                    let row = w * nticks;
                    for tt in 0..t {
                        let Some(k) = spec.tick_of(*tb as i64 + tt as i64) else {
                            continue;
                        };
                        grid.data[row + k] += vals[pp * t + tt];
                    }
                }
                bins += p * t;
            }
        }
        Ok(FusedOutput {
            depos: kept.len(),
            bins,
            timings,
        })
    }
}

// Integration tests (needing built artifacts) live in rust/tests/.
