//! Graph assembly: nodes + edges with validation.
//!
//! A graph is a linear-izable DAG of one source, N function nodes and
//! one sink per chain; edges are declared explicitly and validated
//! (acyclic, connected, single producer per input port) before any
//! engine runs it — the same "assemble then execute" model as WCT.

use super::{FunctionNode, SinkNode, SourceNode};
use std::collections::BTreeMap;
use std::fmt;

/// Node handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// Graph assembly errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// An edge references a node that does not exist.
    UnknownNode(usize),
    /// A cycle was detected.
    Cycle,
    /// A node other than the source has no incoming edge.
    Disconnected(String),
    /// Two edges feed the same consumer.
    DuplicateInput(String),
    /// Source/sink multiplicity is wrong.
    Shape(String),
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::UnknownNode(i) => write!(f, "edge references unknown node {i}"),
            GraphError::Cycle => write!(f, "graph contains a cycle"),
            GraphError::Disconnected(n) => write!(f, "node '{n}' has no input"),
            GraphError::DuplicateInput(n) => write!(f, "node '{n}' has multiple inputs"),
            GraphError::Shape(m) => write!(f, "bad graph shape: {m}"),
        }
    }
}

impl std::error::Error for GraphError {}

pub(super) enum NodeKind {
    Source(Box<dyn SourceNode>),
    Function(Box<dyn FunctionNode>),
    Sink(Box<dyn SinkNode>),
}

impl NodeKind {
    pub(super) fn name(&self) -> String {
        match self {
            NodeKind::Source(n) => n.name(),
            NodeKind::Function(n) => n.name(),
            NodeKind::Sink(n) => n.name(),
        }
    }
}

/// A dataflow graph under assembly.
pub struct Graph {
    pub(super) nodes: Vec<NodeKind>,
    /// edges[from] = to
    pub(super) edges: BTreeMap<usize, usize>,
}

impl Default for Graph {
    fn default() -> Self {
        Self::new()
    }
}

impl Graph {
    /// Empty graph.
    pub fn new() -> Self {
        Self {
            nodes: Vec::new(),
            edges: BTreeMap::new(),
        }
    }

    /// Add a source node.
    pub fn add_source(&mut self, node: Box<dyn SourceNode>) -> NodeId {
        self.nodes.push(NodeKind::Source(node));
        NodeId(self.nodes.len() - 1)
    }

    /// Add a function node.
    pub fn add_function(&mut self, node: Box<dyn FunctionNode>) -> NodeId {
        self.nodes.push(NodeKind::Function(node));
        NodeId(self.nodes.len() - 1)
    }

    /// Add a sink node.
    pub fn add_sink(&mut self, node: Box<dyn SinkNode>) -> NodeId {
        self.nodes.push(NodeKind::Sink(node));
        NodeId(self.nodes.len() - 1)
    }

    /// Connect `from` → `to`.
    pub fn connect(&mut self, from: NodeId, to: NodeId) {
        self.edges.insert(from.0, to.0);
    }

    /// Validate the assembled graph and return the execution order
    /// (source → … → sink).
    pub fn validate(&self) -> Result<Vec<usize>, GraphError> {
        let n = self.nodes.len();
        if n == 0 {
            return Err(GraphError::Shape("empty graph".into()));
        }
        for (&from, &to) in &self.edges {
            if from >= n {
                return Err(GraphError::UnknownNode(from));
            }
            if to >= n {
                return Err(GraphError::UnknownNode(to));
            }
        }
        // single producer per consumer
        let mut indeg = vec![0usize; n];
        for &to in self.edges.values() {
            indeg[to] += 1;
            if indeg[to] > 1 {
                return Err(GraphError::DuplicateInput(self.nodes[to].name()));
            }
        }
        // exactly one source at the head of the chain
        let sources: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k, NodeKind::Source(_)))
            .map(|(i, _)| i)
            .collect();
        if sources.len() != 1 {
            return Err(GraphError::Shape(format!(
                "need exactly 1 source, got {}",
                sources.len()
            )));
        }
        // every non-source must have an input
        for (i, node) in self.nodes.iter().enumerate() {
            if !matches!(node, NodeKind::Source(_)) && indeg[i] == 0 {
                return Err(GraphError::Disconnected(node.name()));
            }
        }
        // walk the chain from the source; detect cycles by step count
        let mut order = vec![sources[0]];
        let mut cur = sources[0];
        let mut steps = 0;
        while let Some(&next) = self.edges.get(&cur) {
            order.push(next);
            cur = next;
            steps += 1;
            if steps > n {
                return Err(GraphError::Cycle);
            }
        }
        // the chain must end at a sink and cover all nodes
        if !matches!(self.nodes[cur], NodeKind::Sink(_)) {
            return Err(GraphError::Shape(format!(
                "chain ends at non-sink '{}'",
                self.nodes[cur].name()
            )));
        }
        if order.len() != n {
            return Err(GraphError::Shape(format!(
                "{} of {} nodes reachable from source",
                order.len(),
                n
            )));
        }
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Payload, SinkNode, SourceNode};
    use super::*;

    struct NullSource(usize);
    impl SourceNode for NullSource {
        fn name(&self) -> String {
            "null-src".into()
        }
        fn next(&mut self) -> Option<Payload> {
            if self.0 == 0 {
                None
            } else {
                self.0 -= 1;
                Some(Payload::Eos)
            }
        }
    }

    struct NullSink;
    impl SinkNode for NullSink {
        fn name(&self) -> String {
            "null-sink".into()
        }
        fn consume(&mut self, _p: Payload) {}
    }

    struct Identity;
    impl super::super::FunctionNode for Identity {
        fn name(&self) -> String {
            "identity".into()
        }
        fn call(&mut self, input: Payload) -> Vec<Payload> {
            vec![input]
        }
    }

    #[test]
    fn valid_chain() {
        let mut g = Graph::new();
        let s = g.add_source(Box::new(NullSource(1)));
        let f = g.add_function(Box::new(Identity));
        let k = g.add_sink(Box::new(NullSink));
        g.connect(s, f);
        g.connect(f, k);
        assert_eq!(g.validate().unwrap(), vec![s.0, f.0, k.0]);
    }

    #[test]
    fn rejects_empty() {
        assert!(matches!(Graph::new().validate(), Err(GraphError::Shape(_))));
    }

    #[test]
    fn rejects_disconnected() {
        let mut g = Graph::new();
        let _s = g.add_source(Box::new(NullSource(1)));
        let _f = g.add_function(Box::new(Identity));
        assert!(matches!(g.validate(), Err(GraphError::Disconnected(_))));
    }

    #[test]
    fn rejects_cycle() {
        let mut g = Graph::new();
        let s = g.add_source(Box::new(NullSource(1)));
        let f1 = g.add_function(Box::new(Identity));
        let f2 = g.add_function(Box::new(Identity));
        g.connect(s, f1);
        g.connect(f1, f2);
        g.connect(f2, f1); // cycle, also duplicate input on f1
        let err = g.validate().unwrap_err();
        assert!(
            matches!(err, GraphError::Cycle | GraphError::DuplicateInput(_)),
            "{err}"
        );
    }

    #[test]
    fn rejects_two_sources() {
        let mut g = Graph::new();
        let _ = g.add_source(Box::new(NullSource(1)));
        let _ = g.add_source(Box::new(NullSource(1)));
        assert!(matches!(g.validate(), Err(GraphError::Shape(_))));
    }

    #[test]
    fn rejects_chain_not_ending_in_sink() {
        let mut g = Graph::new();
        let s = g.add_source(Box::new(NullSource(1)));
        let f = g.add_function(Box::new(Identity));
        g.connect(s, f);
        assert!(matches!(g.validate(), Err(GraphError::Shape(_))));
    }

    #[test]
    fn rejects_unknown_node_edge() {
        let mut g = Graph::new();
        let s = g.add_source(Box::new(NullSource(1)));
        g.connect(s, NodeId(99));
        assert!(matches!(g.validate(), Err(GraphError::UnknownNode(99))));
    }
}
