//! Dataflow framework — the Wire-Cell Toolkit programming-model analog.
//!
//! WCT "supports a modular computing model by expressing computing
//! tasks as nodes of a graph ... executed by various processing
//! engines" (paper §2.1.2).  This module reproduces that framework
//! shape: typed payloads flowing through polymorphic nodes assembled
//! into a DAG, executed by a serial engine or a pipelined threaded
//! engine (the TBB analog).  It also reproduces the §4.2.2 lifecycle
//! concern: backends that need global init/finalize (Kokkos there,
//! PJRT here) register [`Terminal`] hooks that run before the program
//! exits, in reverse registration order — WCT's `ITerminal` stack.

mod engine;
mod graph;

pub use engine::{run_pooled, run_serial, run_threaded};
pub use graph::{Graph, GraphError, NodeId};

use crate::depo::Depo;
use crate::frame::Frame;
use crate::raster::Patch;
use crate::scatter::PlaneGrid;

/// The payload that flows along graph edges.
#[derive(Debug)]
pub enum Payload {
    /// A whole event in a multi-event stream: sequence number, the
    /// per-event seed, and (optionally pre-generated) depos.  Workers
    /// that receive an `Event` with empty depos generate them from the
    /// seed, keeping the shared source cheap under its lock.
    Event {
        /// Position in the stream (0-based).
        seq: u64,
        /// Seed every stochastic stage of this event derives from.
        seed: u64,
        /// The event's depos; may be empty (generate-on-worker).
        depos: Vec<Depo>,
    },
    /// A set of depos.
    Depos(Vec<Depo>),
    /// Rasterized patches plus their plane tag.
    Patches(usize, Vec<Patch>),
    /// An accumulated plane grid.
    Grid(usize, PlaneGrid),
    /// A measured (post-FT) plane waveform grid.
    Signal(usize, Vec<f64>),
    /// A complete event frame.
    Frame(Frame),
    /// End-of-stream marker.
    Eos,
}

impl Payload {
    /// Human-readable tag for diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Event { .. } => "event",
            Payload::Depos(_) => "depos",
            Payload::Patches(..) => "patches",
            Payload::Grid(..) => "grid",
            Payload::Signal(..) => "signal",
            Payload::Frame(_) => "frame",
            Payload::Eos => "eos",
        }
    }
}

/// A source node: produces payloads until exhausted.
pub trait SourceNode: Send {
    /// Descriptive name.
    fn name(&self) -> String;
    /// Next payload, or None when exhausted.
    fn next(&mut self) -> Option<Payload>;
}

/// A function node: transforms one payload into zero or more outputs.
pub trait FunctionNode: Send {
    /// Descriptive name.
    fn name(&self) -> String;
    /// Transform.
    fn call(&mut self, input: Payload) -> Vec<Payload>;
}

/// A sink node: consumes payloads.
pub trait SinkNode: Send {
    /// Descriptive name.
    fn name(&self) -> String;
    /// Consume.
    fn consume(&mut self, input: Payload);
}

/// Finalize hook (WCT `ITerminal` analog).
pub trait Terminal: Send {
    /// Called once at teardown, reverse registration order.
    fn finalize(&mut self);
}

/// A stack of finalize hooks, run in reverse registration order.
#[derive(Default)]
pub struct TerminalStack {
    hooks: Vec<Box<dyn Terminal>>,
}

impl TerminalStack {
    /// New empty stack.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a hook.
    pub fn register(&mut self, hook: Box<dyn Terminal>) {
        self.hooks.push(hook);
    }

    /// Run and clear all hooks (LIFO).
    pub fn finalize_all(&mut self) {
        while let Some(mut h) = self.hooks.pop() {
            h.finalize();
        }
    }

    /// Number of pending hooks.
    pub fn len(&self) -> usize {
        self.hooks.len()
    }

    /// True when no hooks are registered.
    pub fn is_empty(&self) -> bool {
        self.hooks.is_empty()
    }
}

impl Drop for TerminalStack {
    fn drop(&mut self) {
        self.finalize_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    struct Recorder(Arc<AtomicUsize>, usize, Arc<std::sync::Mutex<Vec<usize>>>);
    impl Terminal for Recorder {
        fn finalize(&mut self) {
            self.0.fetch_add(1, Ordering::SeqCst);
            self.2.lock().unwrap().push(self.1);
        }
    }

    #[test]
    fn terminal_stack_runs_lifo() {
        let count = Arc::new(AtomicUsize::new(0));
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut stack = TerminalStack::new();
        for i in 0..3 {
            stack.register(Box::new(Recorder(count.clone(), i, order.clone())));
        }
        assert_eq!(stack.len(), 3);
        stack.finalize_all();
        assert_eq!(count.load(Ordering::SeqCst), 3);
        assert_eq!(*order.lock().unwrap(), vec![2, 1, 0]);
        assert!(stack.is_empty());
    }

    #[test]
    fn terminal_stack_runs_on_drop() {
        let count = Arc::new(AtomicUsize::new(0));
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        {
            let mut stack = TerminalStack::new();
            stack.register(Box::new(Recorder(count.clone(), 9, order.clone())));
        }
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn payload_kinds() {
        assert_eq!(Payload::Eos.kind(), "eos");
        assert_eq!(Payload::Depos(vec![]).kind(), "depos");
        assert_eq!(Payload::Patches(0, vec![]).kind(), "patches");
        assert_eq!(
            Payload::Event {
                seq: 0,
                seed: 1,
                depos: vec![]
            }
            .kind(),
            "event"
        );
    }
}
