//! Execution engines: serial, pipelined-threaded (the TBB analog), and
//! a pooled work-stealing variant for multi-event throughput runs.

use super::graph::{Graph, GraphError, NodeKind};
use super::{FunctionNode, Payload, SinkNode, SourceNode};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};

/// Run the graph on the calling thread: pull from the source, push each
/// payload through the chain, finish with an EOS sweep.
pub fn run_serial(graph: Graph) -> Result<EngineReport, GraphError> {
    let order = graph.validate()?;
    let mut nodes = graph.nodes;
    let mut report = EngineReport::default();
    loop {
        // take from source
        let payload = match &mut nodes[order[0]] {
            NodeKind::Source(s) => s.next(),
            _ => unreachable!("validated head is a source"),
        };
        let Some(payload) = payload else {
            break;
        };
        report.produced += 1;
        // push through functions to the sink
        let mut inflight = vec![payload];
        for &idx in &order[1..] {
            let mut next = Vec::new();
            for p in inflight {
                match &mut nodes[idx] {
                    NodeKind::Function(f) => next.extend(f.call(p)),
                    NodeKind::Sink(s) => {
                        s.consume(p);
                        report.consumed += 1;
                    }
                    NodeKind::Source(_) => unreachable!(),
                }
            }
            inflight = next;
        }
    }
    Ok(report)
}

/// Run the graph with one thread per node connected by channels —
/// pipeline parallelism in the style of `tbb::flow` used by WCT.
/// Bounded channels provide backpressure (`capacity` payloads per edge).
pub fn run_threaded(graph: Graph, capacity: usize) -> Result<EngineReport, GraphError> {
    let order = graph.validate()?;
    let mut nodes: Vec<Option<NodeKind>> = graph.nodes.into_iter().map(Some).collect();
    let mut report = EngineReport::default();

    std::thread::scope(|scope| {
        // build channel chain: n nodes -> n-1 edges
        let mut senders: Vec<mpsc::SyncSender<Payload>> = Vec::new();
        let mut receivers: Vec<mpsc::Receiver<Payload>> = Vec::new();
        for _ in 1..order.len() {
            let (tx, rx) = mpsc::sync_channel(capacity.max(1));
            senders.push(tx);
            receivers.push(rx);
        }
        receivers.reverse(); // pop from the back = edge order

        let mut handles = Vec::new();
        for (pos, &idx) in order.iter().enumerate() {
            let node = nodes[idx].take().unwrap();
            let tx = if pos < senders.len() {
                Some(senders[pos].clone())
            } else {
                None
            };
            let rx = if pos > 0 { receivers.pop() } else { None };
            handles.push(scope.spawn(move || -> (u64, u64) {
                let mut produced = 0u64;
                let mut consumed = 0u64;
                match node {
                    NodeKind::Source(mut s) => {
                        let tx = tx.expect("source has a downstream");
                        while let Some(p) = s.next() {
                            produced += 1;
                            if tx.send(p).is_err() {
                                break;
                            }
                        }
                        // dropping tx closes the edge -> downstream stops
                    }
                    NodeKind::Function(mut f) => {
                        let rx = rx.expect("function has an upstream");
                        let tx = tx.expect("function has a downstream");
                        while let Ok(p) = rx.recv() {
                            for out in f.call(p) {
                                if tx.send(out).is_err() {
                                    return (produced, consumed);
                                }
                            }
                        }
                    }
                    NodeKind::Sink(mut s) => {
                        let rx = rx.expect("sink has an upstream");
                        while let Ok(p) = rx.recv() {
                            s.consume(p);
                            consumed += 1;
                        }
                    }
                }
                (produced, consumed)
            }));
        }
        drop(senders); // only clones held by threads keep edges alive
        for h in handles {
            let (p, c) = h.join().expect("engine thread panicked");
            report.produced += p;
            report.consumed += c;
        }
    });
    Ok(report)
}

/// Run a source → chain → sink pipeline on a pool of `workers` threads,
/// each owning a private copy of the function chain.
///
/// This is the engine variant behind the multi-event throughput runs
/// (`throughput::run_stream`): the serial and threaded engines keep one
/// payload per *stage* in flight, while here up to `workers` payloads
/// are in flight at once, each carried end-to-end by one worker.  Work
/// distribution is pull-based (a natural work-stealing discipline): an
/// idle worker locks the shared source, takes the next payload, and
/// runs it through its own chain, so fast workers automatically absorb
/// more of the stream and stragglers never block the pool.
///
/// `make_chain(w)` is called once per worker `w` (on that worker's
/// thread) and must return the private node chain the worker will own
/// for the whole run — this is where per-worker state (a pipeline, a
/// backend, cached plans) lives.  The source and sink are shared behind
/// mutexes; keep them cheap and push heavy work into the chain.
pub fn run_pooled<F>(
    source: Box<dyn SourceNode>,
    sink: Box<dyn SinkNode>,
    workers: usize,
    make_chain: F,
) -> EngineReport
where
    F: Fn(usize) -> Vec<Box<dyn FunctionNode>> + Sync,
{
    let workers = workers.max(1);
    let source = Mutex::new(source);
    let sink = Mutex::new(sink);
    let produced = AtomicU64::new(0);
    let consumed = AtomicU64::new(0);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let (source, sink) = (&source, &sink);
            let (produced, consumed) = (&produced, &consumed);
            let make_chain = &make_chain;
            handles.push(scope.spawn(move || {
                let mut chain = make_chain(w);
                loop {
                    // Pull the next payload; the lock scope covers only
                    // the take so co-workers overlap on the chain work.
                    let payload = source.lock().unwrap().next();
                    let Some(payload) = payload else {
                        break;
                    };
                    produced.fetch_add(1, Ordering::Relaxed);
                    let mut inflight = vec![payload];
                    for node in chain.iter_mut() {
                        let mut next = Vec::new();
                        for p in inflight {
                            next.extend(node.call(p));
                        }
                        inflight = next;
                    }
                    if !inflight.is_empty() {
                        let mut snk = sink.lock().unwrap();
                        for p in inflight {
                            snk.consume(p);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("pooled engine worker panicked");
        }
    });
    EngineReport {
        produced: produced.load(Ordering::Relaxed),
        consumed: consumed.load(Ordering::Relaxed),
    }
}

/// Counters from an engine run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineReport {
    /// Payloads emitted by the source.
    pub produced: u64,
    /// Payloads absorbed by the sink.
    pub consumed: u64,
}

#[cfg(test)]
mod tests {
    use super::super::{FunctionNode, Payload, SinkNode, SourceNode};
    use super::*;
    use crate::depo::Depo;
    use std::sync::{Arc, Mutex};

    struct CountSource(u64);
    impl SourceNode for CountSource {
        fn name(&self) -> String {
            "count".into()
        }
        fn next(&mut self) -> Option<Payload> {
            if self.0 == 0 {
                return None;
            }
            self.0 -= 1;
            Some(Payload::Depos(vec![Depo::point(
                self.0 as f64,
                [0.0; 3],
                1.0,
                self.0,
            )]))
        }
    }

    /// Doubles each depo's charge.
    struct Doubler;
    impl FunctionNode for Doubler {
        fn name(&self) -> String {
            "doubler".into()
        }
        fn call(&mut self, input: Payload) -> Vec<Payload> {
            match input {
                Payload::Depos(mut d) => {
                    for x in &mut d {
                        x.charge *= 2.0;
                    }
                    vec![Payload::Depos(d)]
                }
                other => vec![other],
            }
        }
    }

    #[derive(Clone)]
    struct Collect(Arc<Mutex<f64>>);
    impl SinkNode for Collect {
        fn name(&self) -> String {
            "collect".into()
        }
        fn consume(&mut self, input: Payload) {
            if let Payload::Depos(d) = input {
                *self.0.lock().unwrap() += d.iter().map(|x| x.charge).sum::<f64>();
            }
        }
    }

    fn build(n: u64, sink: Collect) -> Graph {
        let mut g = Graph::new();
        let s = g.add_source(Box::new(CountSource(n)));
        let f = g.add_function(Box::new(Doubler));
        let k = g.add_sink(Box::new(sink));
        g.connect(s, f);
        g.connect(f, k);
        g
    }

    #[test]
    fn serial_engine_processes_all() {
        let total = Arc::new(Mutex::new(0.0));
        let report = run_serial(build(10, Collect(total.clone()))).unwrap();
        assert_eq!(report.produced, 10);
        assert_eq!(report.consumed, 10);
        assert_eq!(*total.lock().unwrap(), 20.0); // 10 depos x charge 2
    }

    #[test]
    fn threaded_engine_matches_serial() {
        let t1 = Arc::new(Mutex::new(0.0));
        let t2 = Arc::new(Mutex::new(0.0));
        run_serial(build(100, Collect(t1.clone()))).unwrap();
        let report = run_threaded(build(100, Collect(t2.clone())), 4).unwrap();
        assert_eq!(*t1.lock().unwrap(), *t2.lock().unwrap());
        assert_eq!(report.consumed, 100);
    }

    #[test]
    fn threaded_with_tiny_capacity_backpressures_correctly() {
        let total = Arc::new(Mutex::new(0.0));
        let report = run_threaded(build(50, Collect(total.clone())), 1).unwrap();
        assert_eq!(report.consumed, 50);
        assert_eq!(*total.lock().unwrap(), 100.0);
    }

    #[test]
    fn invalid_graph_rejected_by_engines() {
        let g = Graph::new();
        assert!(run_serial(g).is_err());
        let g = Graph::new();
        assert!(run_threaded(g, 2).is_err());
    }

    #[test]
    fn pooled_engine_matches_serial() {
        let t1 = Arc::new(Mutex::new(0.0));
        let t2 = Arc::new(Mutex::new(0.0));
        run_serial(build(100, Collect(t1.clone()))).unwrap();
        let report = run_pooled(
            Box::new(CountSource(100)),
            Box::new(Collect(t2.clone())),
            4,
            |_| vec![Box::new(Doubler) as Box<dyn FunctionNode>],
        );
        assert_eq!(report.produced, 100);
        assert_eq!(report.consumed, 100);
        assert_eq!(*t1.lock().unwrap(), *t2.lock().unwrap());
    }

    #[test]
    fn pooled_engine_single_worker() {
        let total = Arc::new(Mutex::new(0.0));
        let report = run_pooled(
            Box::new(CountSource(10)),
            Box::new(Collect(total.clone())),
            1,
            |_| vec![Box::new(Doubler) as Box<dyn FunctionNode>],
        );
        assert_eq!(report.consumed, 10);
        assert_eq!(*total.lock().unwrap(), 20.0);
    }

    #[test]
    fn pooled_engine_multi_stage_chains() {
        // each worker owns a private two-stage chain: charge x4
        let total = Arc::new(Mutex::new(0.0));
        let report = run_pooled(
            Box::new(CountSource(25)),
            Box::new(Collect(total.clone())),
            3,
            |_| {
                vec![
                    Box::new(Doubler) as Box<dyn FunctionNode>,
                    Box::new(Doubler) as Box<dyn FunctionNode>,
                ]
            },
        );
        assert_eq!(report.produced, 25);
        assert_eq!(*total.lock().unwrap(), 100.0);
    }

    #[test]
    fn pooled_engine_empty_source() {
        let total = Arc::new(Mutex::new(0.0));
        let report = run_pooled(
            Box::new(CountSource(0)),
            Box::new(Collect(total.clone())),
            4,
            |_| vec![Box::new(Doubler) as Box<dyn FunctionNode>],
        );
        assert_eq!(report, EngineReport::default());
    }

    #[test]
    fn multi_stage_pipeline() {
        // source -> doubler -> doubler -> sink: charge x4
        let total = Arc::new(Mutex::new(0.0));
        let mut g = Graph::new();
        let s = g.add_source(Box::new(CountSource(5)));
        let f1 = g.add_function(Box::new(Doubler));
        let f2 = g.add_function(Box::new(Doubler));
        let k = g.add_sink(Box::new(Collect(total.clone())));
        g.connect(s, f1);
        g.connect(f1, f2);
        g.connect(f2, k);
        run_threaded(g, 2).unwrap();
        assert_eq!(*total.lock().unwrap(), 20.0);
    }
}
