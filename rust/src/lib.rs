//! # wirecell — LArTPC signal simulation with portable acceleration
//!
//! A ground-up reproduction of the system studied in *"Evaluation of
//! Portable Acceleration Solutions for LArTPC Simulation Using Wire-Cell
//! Toolkit"* (EPJ Web Conf. 251, 03032, 2021): the Wire-Cell Toolkit
//! LArTPC detector-signal simulation, re-implemented as a three-layer
//! Rust + JAX + Pallas stack, plus the paper's full portability
//! evaluation (Tables 2–3, Figure 5, and the Figure-3 vs Figure-4
//! porting-strategy comparison).
//!
//! See `DESIGN.md` for the system inventory and experiment index and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Layer map
//!
//! * substrates: [`units`], [`rng`], [`fft`], [`json`], [`parallel`],
//!   [`special`], [`testing`]
//! * physics/sim core: [`geometry`], [`depo`], [`physics`], [`drift`],
//!   [`raster`], [`kernel`] (the fused SoA hot path), [`scatter`]
//! * framework + portability: [`session`] (the stage-graph entry
//!   point: `SimStage` components, the string-keyed `Registry`, and
//!   the `SimSession` builder), [`dataflow`], [`backend`], [`runtime`],
//!   [`coordinator`] (the legacy `SimPipeline` shim + node adapters),
//!   [`metrics`], [`cli`]
//! * scale-out: [`scenario`] — named multi-APA workloads and the
//!   APA-sharded execution path behind `wire-cell scenarios` —
//!   [`throughput`] — the multi-event worker-pool engine behind
//!   `wire-cell throughput` — and [`serve`] — the persistent
//!   streaming service (binary wire protocol, frame arena, admission
//!   control, Prometheus metrics) behind `wire-cell serve`
//!
//! See `README.md` for the quickstart, `docs/ARCHITECTURE.md` for the
//! full layer walk-through (including the `SimPipeline` → `SimSession`
//! migration note and the stage-authoring guide), `docs/SCENARIOS.md`
//! for the workload catalog, and `docs/KERNELS.md` for the
//! fused-kernel memory layout and execution model.

#![warn(missing_docs)]
// ci.sh runs `cargo clippy -- -D warnings`; these are the project-wide
// style dispensations (each is a deliberate idiom, not an oversight).
#![allow(clippy::new_without_default)] // zero-arg `new` kept symmetric with configured constructors
#![allow(clippy::too_many_arguments)] // kernel entry points mirror the paper's parameter vectors
#![allow(clippy::needless_range_loop)] // index loops double as bin-coordinate arithmetic
#![allow(clippy::field_reassign_with_default)] // config-override style: default() then overrides

pub mod adc;
pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod depo;
pub mod drift;
pub mod fft;
pub mod frame;
pub mod geometry;
pub mod harness;
pub mod json;
pub mod kernel;
pub mod metrics;
pub mod parallel;
pub mod physics;
pub mod noise;
pub mod raster;
pub mod response;
pub mod rng;
pub mod runtime;
pub mod scatter;
pub mod scenario;
pub mod serve;
pub mod session;
pub mod sigproc;
pub mod simd;
pub mod special;
pub mod testing;
pub mod throughput;
pub mod units;
