//! # wirecell — LArTPC signal simulation with portable acceleration
//!
//! A ground-up reproduction of the system studied in *"Evaluation of
//! Portable Acceleration Solutions for LArTPC Simulation Using Wire-Cell
//! Toolkit"* (EPJ Web Conf. 251, 03032, 2021): the Wire-Cell Toolkit
//! LArTPC detector-signal simulation, re-implemented as a three-layer
//! Rust + JAX + Pallas stack, plus the paper's full portability
//! evaluation (Tables 2–3, Figure 5, and the Figure-3 vs Figure-4
//! porting-strategy comparison).
//!
//! See `DESIGN.md` for the system inventory and experiment index and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Layer map
//!
//! * substrates: [`units`], [`rng`], [`fft`], [`json`], [`parallel`],
//!   [`special`], [`testing`]
//! * physics/sim core: [`geometry`], [`depo`], [`physics`], [`drift`],
//!   [`raster`], [`scatter`]
//! * framework + portability: [`dataflow`], [`backend`], [`runtime`],
//!   [`coordinator`], [`metrics`], [`cli`]
//! * scale-out: [`throughput`] — the multi-event worker-pool engine
//!   behind `wire-cell throughput`
//!
//! See `README.md` for the quickstart and `docs/ARCHITECTURE.md` for
//! the full layer walk-through.

#![warn(missing_docs)]

pub mod adc;
pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod depo;
pub mod drift;
pub mod fft;
pub mod frame;
pub mod geometry;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod parallel;
pub mod physics;
pub mod noise;
pub mod raster;
pub mod response;
pub mod rng;
pub mod runtime;
pub mod scatter;
pub mod sigproc;
pub mod special;
pub mod testing;
pub mod throughput;
pub mod units;
