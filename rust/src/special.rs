//! Special functions needed by the simulation (std has no `erf`).
//!
//! `erf`/`erfc` use the rational Chebyshev-style approximation from
//! Numerical Recipes (`erfc` with fractional error < 1.2e-7 everywhere),
//! which is ample for charge-fraction weights; the Gaussian bin-integral
//! helper is the primitive the rasterizer's "2D sampling" step is built
//! from.

// Chebyshev coefficients (Numerical Recipes 3rd ed., erfc_cheb).
// Shared between the scalar path and the lockstep lane path
// (`erf_block`) — both must run the identical recurrence for the
// vector axis tables to stay bit-identical to the scalar oracle.
const ERFC_COF: [f64; 28] = [
    -1.3026537197817094,
    6.4196979235649026e-1,
    1.9476473204185836e-2,
    -9.561514786808631e-3,
    -9.46595344482036e-4,
    3.66839497852761e-4,
    4.2523324806907e-5,
    -2.0278578112534e-5,
    -1.624290004647e-6,
    1.303655835580e-6,
    1.5626441722e-8,
    -8.5238095915e-8,
    6.529054439e-9,
    5.059343495e-9,
    -9.91364156e-10,
    -2.27365122e-10,
    9.6467911e-11,
    2.394038e-12,
    -6.886027e-12,
    8.94487e-13,
    3.13092e-13,
    -1.12708e-13,
    3.81e-16,
    7.106e-15,
    -1.523e-15,
    -9.4e-17,
    1.21e-16,
    -2.8e-17,
];

/// Complementary error function, |fractional error| < 1.2e-7.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    let mut d = 0.0;
    let mut dd = 0.0;
    for &c in ERFC_COF.iter().rev().take(ERFC_COF.len() - 1) {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (ERFC_COF[0] + ty * d) - dd).exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Error function.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Lockstep lane evaluation of [`erf`] over one `[f64; W]` chunk — the
/// vector form behind the SIMD axis-table fill (`crate::raster`,
/// `crate::kernel::soa`).
///
/// Every lane executes exactly the scalar [`erfc`] operation sequence
/// (abs, the `t`/`ty` transforms, the Chebyshev–Clenshaw recurrence
/// over [`ERFC_COF`] in the same order, the final `exp` and sign
/// select), just interleaved element-major so the fixed-width inner
/// loops auto-vectorize.  IEEE f64 arithmetic is deterministic per
/// operation and nothing here reassociates, so each output is
/// **bit-identical** to `erf(xs[j])` — including the ±0.0, ±inf and
/// NaN edge cases (asserted below and in `rust/tests/simd.rs`).
#[inline]
pub fn erf_block<const W: usize>(xs: [f64; W]) -> [f64; W] {
    let mut z = [0.0f64; W];
    let mut t = [0.0f64; W];
    let mut ty = [0.0f64; W];
    for j in 0..W {
        z[j] = xs[j].abs();
    }
    for j in 0..W {
        t[j] = 2.0 / (2.0 + z[j]);
    }
    for j in 0..W {
        ty[j] = 4.0 * t[j] - 2.0;
    }
    let mut d = [0.0f64; W];
    let mut dd = [0.0f64; W];
    for &c in ERFC_COF.iter().rev().take(ERFC_COF.len() - 1) {
        for j in 0..W {
            let tmp = d[j];
            d[j] = ty[j] * d[j] - dd[j] + c;
            dd[j] = tmp;
        }
    }
    let mut out = [0.0f64; W];
    for j in 0..W {
        let ans = t[j] * (-z[j] * z[j] + 0.5 * (ERFC_COF[0] + ty[j] * d[j]) - dd[j]).exp();
        out[j] = 1.0 - if xs[j] >= 0.0 { ans } else { 2.0 - ans };
    }
    out
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Integral of a Gaussian N(mu, sigma) over [a, b] — the probability
/// mass a rasterized bin receives.
pub fn gauss_bin_integral(mu: f64, sigma: f64, a: f64, b: f64) -> f64 {
    debug_assert!(b >= a);
    if sigma <= 0.0 {
        // Degenerate: all mass at mu.
        return if mu >= a && mu < b { 1.0 } else { 0.0 };
    }
    let inv = 1.0 / (sigma * std::f64::consts::SQRT_2);
    0.5 * (erf((b - mu) * inv) - erf((a - mu) * inv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (3.0, 0.9999779095),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!((got - want).abs() < 2e-7, "erf({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn erfc_complements_erf() {
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn erf_is_odd() {
        for i in 0..50 {
            let x = i as f64 * 0.07;
            assert!((erf(x) + erf(-x)).abs() < 2e-7);
        }
    }

    #[test]
    fn erfc_tails() {
        assert!(erfc(6.0) < 1e-16);
        assert!((erfc(-6.0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn erf_high_precision_anchors() {
        // 17-digit reference values (mpmath); the NR Chebyshev fit is
        // documented at |fractional error| < 1.2e-7, so the assert
        // pins the oracle to its full stated envelope against anchors
        // that are themselves exact to the last f64 digit.
        let cases = [
            (0.1, 0.112_462_916_018_284_89),
            (0.25, 0.276_326_390_168_236_93),
            (0.5, 0.520_499_877_813_046_5),
            (1.0, 0.842_700_792_949_714_9),
            (1.5, 0.966_105_146_475_310_7),
            (2.0, 0.995_322_265_018_952_7),
            (2.5, 0.999_593_047_982_555_3),
            (3.0, 0.999_977_909_503_001_4),
            (4.0, 0.999_999_984_582_742_1),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!(
                (got - want).abs() < 1.2e-7 * want.abs().max(1e-30),
                "erf({x}) = {got:.17}, want {want:.17}"
            );
            // the complement must honor the same envelope
            let gotc = erfc(x);
            let wantc = 1.0 - want;
            assert!(
                (gotc - wantc).abs() < 1.2e-7 * wantc.abs() + 1e-12,
                "erfc({x}) = {gotc:e}, want {wantc:e}"
            );
        }
    }

    #[test]
    fn erf_signed_zero() {
        // both zeros land on the same (tiny) value: the sign select
        // treats -0.0 >= 0.0 as true, exactly like +0.0
        assert_eq!(erf(0.0).to_bits(), erf(-0.0).to_bits());
        assert!(erf(0.0).abs() < 1.2e-7);
        assert_eq!(erfc(0.0).to_bits(), erfc(-0.0).to_bits());
        assert!((erfc(0.0) - 1.0).abs() < 1.2e-7);
    }

    #[test]
    fn erf_infinities_saturate_exactly() {
        // exp(-inf) = 0 makes the tails exact, not merely close
        assert_eq!(erf(f64::INFINITY), 1.0);
        assert_eq!(erf(f64::NEG_INFINITY), -1.0);
        assert_eq!(erfc(f64::INFINITY), 0.0);
        assert_eq!(erfc(f64::NEG_INFINITY), 2.0);
    }

    #[test]
    fn erf_nan_propagates() {
        assert!(erf(f64::NAN).is_nan());
        assert!(erfc(f64::NAN).is_nan());
    }

    #[test]
    fn erf_saturates_beyond_six_sigma() {
        // |x| > 6: erfc underflows past f64's 1-ulp of 1.0, so erf
        // rounds to exactly ±1 — the rasterizer relies on this for
        // far-tail bins contributing exactly zero mass
        for x in [6.0, 6.5, 8.0, 12.0, 26.5] {
            assert_eq!(erf(x), 1.0, "erf({x})");
            assert_eq!(erf(-x), -1.0, "erf(-{x})");
            assert!(erfc(x) >= 0.0 && erfc(x) < 1e-16, "erfc({x}) = {:e}", erfc(x));
            assert!((erfc(-x) - 2.0).abs() < 1e-15, "erfc(-{x})");
        }
    }

    #[test]
    fn erf_block_bitwise_matches_scalar() {
        // the lane path is the axis-table fill's oracle contract:
        // every supported width, bit-for-bit, including edge values
        let samples = [
            0.0, -0.0, 0.3, -0.7, 1.0, -1.5, 2.25, -3.5, 6.5, -8.0,
            1e-12, -1e-12, f64::INFINITY, f64::NEG_INFINITY, f64::NAN, 0.5,
        ];
        fn check<const W: usize>(samples: &[f64]) {
            for chunk in samples.chunks_exact(W) {
                let mut xs = [0.0f64; W];
                xs.copy_from_slice(chunk);
                let got = erf_block(xs);
                for j in 0..W {
                    assert_eq!(
                        got[j].to_bits(),
                        erf(xs[j]).to_bits(),
                        "erf_block::<{W}>({}) diverged from scalar",
                        xs[j]
                    );
                }
            }
        }
        check::<1>(&samples);
        check::<2>(&samples);
        check::<4>(&samples);
        check::<8>(&samples);
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.6448536269514722) - 0.95).abs() < 1e-7);
        assert!((norm_cdf(-1.959963984540054) - 0.025).abs() < 1e-7);
    }

    #[test]
    fn gauss_integral_total_mass() {
        let total = gauss_bin_integral(0.0, 1.0, -10.0, 10.0);
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gauss_integral_symmetric_halves() {
        let left = gauss_bin_integral(0.0, 2.0, -20.0, 0.0);
        let right = gauss_bin_integral(0.0, 2.0, 0.0, 20.0);
        assert!((left - 0.5).abs() < 1e-9);
        assert!((right - 0.5).abs() < 1e-9);
    }

    #[test]
    fn gauss_integral_degenerate_sigma() {
        assert_eq!(gauss_bin_integral(0.5, 0.0, 0.0, 1.0), 1.0);
        assert_eq!(gauss_bin_integral(1.5, 0.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn property_bin_integrals_partition() {
        crate::testing::forall("gauss bin integrals sum to ~1", 100, |g| {
            let mu = g.f64_in(-5.0..5.0);
            let sigma = g.f64_in(0.01..3.0);
            let n = g.usize_in(10..200);
            let lo = mu - 8.0 * sigma;
            let hi = mu + 8.0 * sigma;
            let w = (hi - lo) / n as f64;
            let total: f64 = (0..n)
                .map(|i| gauss_bin_integral(mu, sigma, lo + i as f64 * w, lo + (i + 1) as f64 * w))
                .sum();
            g.assert_close(total, 1.0, 1e-6, "partition sums to 1");
        });
    }
}
