//! Special functions needed by the simulation (std has no `erf`).
//!
//! `erf`/`erfc` use the rational Chebyshev-style approximation from
//! Numerical Recipes (`erfc` with fractional error < 1.2e-7 everywhere),
//! which is ample for charge-fraction weights; the Gaussian bin-integral
//! helper is the primitive the rasterizer's "2D sampling" step is built
//! from.

/// Complementary error function, |fractional error| < 1.2e-7.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    // Chebyshev coefficients (Numerical Recipes 3rd ed., erfc_cheb).
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for &c in COF.iter().rev().take(COF.len() - 1) {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Error function.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Integral of a Gaussian N(mu, sigma) over [a, b] — the probability
/// mass a rasterized bin receives.
pub fn gauss_bin_integral(mu: f64, sigma: f64, a: f64, b: f64) -> f64 {
    debug_assert!(b >= a);
    if sigma <= 0.0 {
        // Degenerate: all mass at mu.
        return if mu >= a && mu < b { 1.0 } else { 0.0 };
    }
    let inv = 1.0 / (sigma * std::f64::consts::SQRT_2);
    0.5 * (erf((b - mu) * inv) - erf((a - mu) * inv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (3.0, 0.9999779095),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!((got - want).abs() < 2e-7, "erf({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn erfc_complements_erf() {
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn erf_is_odd() {
        for i in 0..50 {
            let x = i as f64 * 0.07;
            assert!((erf(x) + erf(-x)).abs() < 2e-7);
        }
    }

    #[test]
    fn erfc_tails() {
        assert!(erfc(6.0) < 1e-16);
        assert!((erfc(-6.0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.6448536269514722) - 0.95).abs() < 1e-7);
        assert!((norm_cdf(-1.959963984540054) - 0.025).abs() < 1e-7);
    }

    #[test]
    fn gauss_integral_total_mass() {
        let total = gauss_bin_integral(0.0, 1.0, -10.0, 10.0);
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gauss_integral_symmetric_halves() {
        let left = gauss_bin_integral(0.0, 2.0, -20.0, 0.0);
        let right = gauss_bin_integral(0.0, 2.0, 0.0, 20.0);
        assert!((left - 0.5).abs() < 1e-9);
        assert!((right - 0.5).abs() < 1e-9);
    }

    #[test]
    fn gauss_integral_degenerate_sigma() {
        assert_eq!(gauss_bin_integral(0.5, 0.0, 0.0, 1.0), 1.0);
        assert_eq!(gauss_bin_integral(1.5, 0.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn property_bin_integrals_partition() {
        crate::testing::forall("gauss bin integrals sum to ~1", 100, |g| {
            let mu = g.f64_in(-5.0..5.0);
            let sigma = g.f64_in(0.01..3.0);
            let n = g.usize_in(10..200);
            let lo = mu - 8.0 * sigma;
            let hi = mu + 8.0 * sigma;
            let w = (hi - lo) / n as f64;
            let total: f64 = (0..n)
                .map(|i| gauss_bin_integral(mu, sigma, lo + i as f64 * w, lo + (i + 1) as f64 * w))
                .sum();
            g.assert_close(total, 1.0, 1e-6, "partition sums to 1");
        });
    }
}
