//! Energy depositions and their sources.
//!
//! The paper's benchmark workload is "100k energy depositions generated
//! from simulated cosmic rays" (§4.3.2, CORSIKA + Geant4 + LArSoft).
//! Those generators are not available here, so [`CosmicSource`]
//! synthesizes a statistically comparable workload: muon tracks drawn
//! from a cos²θ zenith distribution, stepped through the active volume
//! with Landau-fluctuated MIP losses (see DESIGN.md §2 for why this
//! preserves the benchmark's behaviour).  [`TrackDepoSource`] and
//! [`PointSource`] cover targeted tests, and JSON I/O round-trips depo
//! sets the way WCT's JSON depo files do.

mod cosmic;
mod io;
mod track;

pub use cosmic::CosmicSource;
pub use io::{depos_from_json, depos_to_json, read_depo_file, write_depo_file};
pub use track::{PointSource, TrackDepoSource};

/// One energy deposition: a point cluster of ionization electrons.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Depo {
    /// Creation time.
    pub time: f64,
    /// Position (x = drift axis, y vertical, z beam).
    pub pos: [f64; 3],
    /// Number of ionization electrons (post-recombination).
    pub charge: f64,
    /// Deposited energy (pre-recombination bookkeeping).
    pub energy: f64,
    /// Longitudinal (drift-time) Gaussian width already accrued.
    pub sigma_l: f64,
    /// Transverse Gaussian width already accrued.
    pub sigma_t: f64,
    /// Identifier (track id or sequence number).
    pub id: u64,
}

impl Depo {
    /// A bare depo with zero extent.
    pub fn point(time: f64, pos: [f64; 3], charge: f64, id: u64) -> Self {
        Self {
            time,
            pos,
            charge,
            energy: 0.0,
            sigma_l: 0.0,
            sigma_t: 0.0,
            id,
        }
    }
}

/// Anything that can produce a set of depos.
pub trait DepoSource {
    /// Generate the depo set.
    fn generate(&mut self) -> Vec<Depo>;

    /// Descriptive label for run metadata.
    fn label(&self) -> String;
}

/// Summary statistics of a depo set (used in run reports and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DepoStats {
    /// Number of depos.
    pub count: usize,
    /// Total charge (electrons).
    pub total_charge: f64,
    /// Charge-weighted mean position.
    pub mean_pos: [f64; 3],
    /// Time range (min, max).
    pub time_range: (f64, f64),
}

/// Compute summary statistics.
pub fn stats(depos: &[Depo]) -> DepoStats {
    if depos.is_empty() {
        return DepoStats::default();
    }
    let total: f64 = depos.iter().map(|d| d.charge).sum();
    let mut mean = [0.0; 3];
    for d in depos {
        for k in 0..3 {
            mean[k] += d.pos[k] * d.charge;
        }
    }
    if total > 0.0 {
        for m in &mut mean {
            *m /= total;
        }
    }
    let tmin = depos.iter().map(|d| d.time).fold(f64::INFINITY, f64::min);
    let tmax = depos.iter().map(|d| d.time).fold(f64::NEG_INFINITY, f64::max);
    DepoStats {
        count: depos.len(),
        total_charge: total,
        mean_pos: mean,
        time_range: (tmin, tmax),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_constructor() {
        let d = Depo::point(1.0, [2.0, 3.0, 4.0], 5000.0, 7);
        assert_eq!(d.sigma_l, 0.0);
        assert_eq!(d.sigma_t, 0.0);
        assert_eq!(d.charge, 5000.0);
        assert_eq!(d.id, 7);
    }

    #[test]
    fn stats_of_empty() {
        let s = stats(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.total_charge, 0.0);
    }

    #[test]
    fn stats_weighted_mean() {
        let depos = vec![
            Depo::point(0.0, [0.0, 0.0, 0.0], 1.0, 0),
            Depo::point(2.0, [2.0, 0.0, 0.0], 3.0, 1),
        ];
        let s = stats(&depos);
        assert_eq!(s.count, 2);
        assert_eq!(s.total_charge, 4.0);
        assert!((s.mean_pos[0] - 1.5).abs() < 1e-12);
        assert_eq!(s.time_range, (0.0, 2.0));
    }
}
