//! Deterministic depo sources: straight-line tracks and point sources.

use super::{Depo, DepoSource};
use crate::physics::MipLoss;
use crate::rng::Pcg32;
use crate::units::*;

/// Steps a straight track between two endpoints, drawing Landau-
/// fluctuated MIP losses per step — the minimal "charged particle"
/// workload for examples and targeted tests.
pub struct TrackDepoSource {
    /// Start point.
    pub start: [f64; 3],
    /// End point.
    pub end: [f64; 3],
    /// Track start time.
    pub time: f64,
    /// Step length between depos.
    pub step: f64,
    /// Energy-loss model.
    pub loss: MipLoss,
    /// RNG seed.
    pub seed: u64,
    /// Track id assigned to the produced depos.
    pub track_id: u64,
}

impl TrackDepoSource {
    /// A MIP track with 1 mm steps and default loss model.
    pub fn mip(start: [f64; 3], end: [f64; 3], time: f64, seed: u64) -> Self {
        Self {
            start,
            end,
            time,
            step: 1.0 * MM,
            loss: MipLoss::default(),
            seed,
            track_id: 0,
        }
    }
}

impl DepoSource for TrackDepoSource {
    fn generate(&mut self) -> Vec<Depo> {
        let mut rng = Pcg32::seeded(self.seed);
        let d = [
            self.end[0] - self.start[0],
            self.end[1] - self.start[1],
            self.end[2] - self.start[2],
        ];
        let length = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt();
        if length <= 0.0 {
            return Vec::new();
        }
        let nsteps = (length / self.step).ceil() as usize;
        let beta_c = 0.9997 * 299.792458 * MM / NS; // ~light speed muon
        let mut depos = Vec::with_capacity(nsteps);
        for i in 0..nsteps {
            // Midpoint of step i.
            let s0 = i as f64 * self.step;
            let s1 = ((i + 1) as f64 * self.step).min(length);
            let smid = 0.5 * (s0 + s1);
            let frac = smid / length;
            let steplen = s1 - s0;
            if steplen <= 0.0 {
                break;
            }
            let (energy, electrons) = self.loss.step(&mut rng, steplen);
            depos.push(Depo {
                time: self.time + smid / beta_c,
                pos: [
                    self.start[0] + frac * d[0],
                    self.start[1] + frac * d[1],
                    self.start[2] + frac * d[2],
                ],
                charge: electrons,
                energy,
                sigma_l: 0.0,
                sigma_t: 0.0,
                id: self.track_id,
            });
        }
        depos
    }

    fn label(&self) -> String {
        format!(
            "track[({:.0},{:.0},{:.0})->({:.0},{:.0},{:.0}) mm, step {:.1} mm]",
            self.start[0] / MM,
            self.start[1] / MM,
            self.start[2] / MM,
            self.end[0] / MM,
            self.end[1] / MM,
            self.end[2] / MM,
            self.step / MM
        )
    }
}

/// A fixed set of identical point depos — the fully deterministic
/// source for kernel-level golden tests.
pub struct PointSource {
    /// The depos to emit.
    pub depos: Vec<Depo>,
}

impl PointSource {
    /// `n` depos of `charge` electrons at `pos`, spaced `dt` in time.
    pub fn repeated(n: usize, pos: [f64; 3], charge: f64, t0: f64, dt: f64) -> Self {
        Self {
            depos: (0..n)
                .map(|i| Depo::point(t0 + i as f64 * dt, pos, charge, i as u64))
                .collect(),
        }
    }
}

impl DepoSource for PointSource {
    fn generate(&mut self) -> Vec<Depo> {
        self.depos.clone()
    }
    fn label(&self) -> String {
        format!("points[n={}]", self.depos.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depo::stats;

    #[test]
    fn track_spans_endpoints() {
        let mut src = TrackDepoSource::mip([0.0, 0.0, 0.0], [0.0, 0.0, 100.0 * MM], 0.0, 1);
        let depos = src.generate();
        assert_eq!(depos.len(), 100);
        assert!(depos[0].pos[2] < 1.0 * MM);
        assert!(depos.last().unwrap().pos[2] > 99.0 * MM);
        // times increase along the track
        assert!(depos.windows(2).all(|w| w[1].time > w[0].time));
    }

    #[test]
    fn track_charge_is_mip_scale() {
        let mut src = TrackDepoSource::mip([0.0, 0.0, 0.0], [0.0, 0.0, 100.0 * MM], 0.0, 2);
        let s = stats(&src.generate());
        // ~6k electrons per mm step on average (58k/cm)
        let per_depo = s.total_charge / s.count as f64;
        assert!((3_000.0..15_000.0).contains(&per_depo), "per_depo={per_depo}");
    }

    #[test]
    fn track_is_deterministic_by_seed() {
        let gen = |seed| {
            TrackDepoSource::mip([0.0, 0.0, 0.0], [10.0 * MM, 0.0, 50.0 * MM], 0.0, seed).generate()
        };
        assert_eq!(gen(5), gen(5));
        assert_ne!(
            gen(5).iter().map(|d| d.charge).sum::<f64>(),
            gen(6).iter().map(|d| d.charge).sum::<f64>()
        );
    }

    #[test]
    fn track_is_bitwise_reproducible() {
        // the scenario engine's determinism rests on generate() being a
        // pure function of the seed: assert full bit equality, not just
        // summary stats
        let gen = |seed| {
            TrackDepoSource::mip([0.0, 0.0, 0.0], [5.0 * MM, 0.0, 80.0 * MM], 2.0, seed).generate()
        };
        let (a, b) = (gen(11), gen(11));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn track_charge_spectrum_is_landau_skewed() {
        // the per-step loss model is Landau-like: a heavy upper tail,
        // so max >> mean > median.  This is the statistical shape the
        // scenario witnesses assume for MIP workloads.
        let depos =
            TrackDepoSource::mip([0.0, 0.0, 0.0], [0.0, 0.0, 2000.0 * MM], 0.0, 17).generate();
        assert_eq!(depos.len(), 2000);
        let mut charges: Vec<f64> = depos.iter().map(|d| d.charge).collect();
        charges.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = charges.iter().sum::<f64>() / charges.len() as f64;
        let median = charges[charges.len() / 2];
        let max = *charges.last().unwrap();
        assert!(mean > median, "mean {mean} <= median {median} (no upper tail)");
        assert!(max > 1.5 * mean, "max {max} vs mean {mean}: tail too light");
        // every step ionizes something
        assert!(charges[0] > 0.0);
    }

    #[test]
    fn degenerate_track_is_empty() {
        let mut src = TrackDepoSource::mip([1.0, 2.0, 3.0], [1.0, 2.0, 3.0], 0.0, 1);
        assert!(src.generate().is_empty());
    }

    #[test]
    fn diagonal_track_midpoint() {
        let mut src = TrackDepoSource::mip([0.0, 0.0, 0.0], [60.0 * MM, 60.0 * MM, 60.0 * MM], 0.0, 3);
        let depos = src.generate();
        let s = stats(&depos);
        for k in 0..3 {
            assert!(
                (s.mean_pos[k] - 30.0 * MM).abs() < 5.0 * MM,
                "axis {k}: {}",
                s.mean_pos[k] / MM
            );
        }
    }

    #[test]
    fn point_source_repeats() {
        let mut src = PointSource::repeated(5, [1.0, 2.0, 3.0], 1000.0, 0.0, 10.0);
        let depos = src.generate();
        assert_eq!(depos.len(), 5);
        assert!(depos.iter().all(|d| d.charge == 1000.0));
        assert_eq!(depos[4].time, 40.0);
        assert_eq!(depos[2].id, 2);
    }
}
