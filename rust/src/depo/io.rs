//! JSON depo-set I/O (WCT-style depo files).
//!
//! Format: `{"depos": [{"t":..,"x":..,"y":..,"z":..,"q":..,"e":..,
//! "sl":..,"st":..,"id":..}, ...]}` — close to the wire-cell-toolkit
//! JSON depo schema, with widths included so drifted sets round-trip.

use super::Depo;
use crate::json::{parse, to_string, Value};
use std::path::Path;

/// Serialize a depo set to a JSON string.
pub fn depos_to_json(depos: &[Depo]) -> String {
    let arr: Vec<Value> = depos
        .iter()
        .map(|d| {
            Value::object(vec![
                ("t", Value::from(d.time)),
                ("x", Value::from(d.pos[0])),
                ("y", Value::from(d.pos[1])),
                ("z", Value::from(d.pos[2])),
                ("q", Value::from(d.charge)),
                ("e", Value::from(d.energy)),
                ("sl", Value::from(d.sigma_l)),
                ("st", Value::from(d.sigma_t)),
                ("id", Value::from(d.id as f64)),
            ])
        })
        .collect();
    to_string(&Value::object(vec![("depos", Value::Array(arr))]))
}

/// Parse a depo set from a JSON string.
pub fn depos_from_json(text: &str) -> Result<Vec<Depo>, String> {
    let doc = parse(text).map_err(|e| e.to_string())?;
    let arr = doc
        .get("depos")
        .and_then(|v| v.as_array())
        .ok_or_else(|| "missing 'depos' array".to_string())?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let f = |key: &str| -> Result<f64, String> {
            item.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("depo {i}: missing number '{key}'"))
        };
        out.push(Depo {
            time: f("t")?,
            pos: [f("x")?, f("y")?, f("z")?],
            charge: f("q")?,
            energy: f("e").unwrap_or(0.0),
            sigma_l: f("sl").unwrap_or(0.0),
            sigma_t: f("st").unwrap_or(0.0),
            id: f("id").unwrap_or(i as f64) as u64,
        });
    }
    Ok(out)
}

/// Write a depo file.
pub fn write_depo_file(path: &Path, depos: &[Depo]) -> std::io::Result<()> {
    std::fs::write(path, depos_to_json(depos))
}

/// Read a depo file.
pub fn read_depo_file(path: &Path) -> Result<Vec<Depo>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    depos_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Depo> {
        vec![
            Depo {
                time: 1.5,
                pos: [10.0, -20.0, 30.0],
                charge: 5000.0,
                energy: 0.12,
                sigma_l: 0.5,
                sigma_t: 0.25,
                id: 3,
            },
            Depo::point(0.0, [0.0, 0.0, 0.0], 1.0, 0),
        ]
    }

    #[test]
    fn json_roundtrip() {
        let depos = sample();
        let text = depos_to_json(&depos);
        let back = depos_from_json(&text).unwrap();
        assert_eq!(depos, back);
    }

    #[test]
    fn file_roundtrip() {
        let depos = sample();
        let path = std::env::temp_dir().join("wct_test_depos.json");
        write_depo_file(&path, &depos).unwrap();
        let back = read_depo_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(depos, back);
    }

    #[test]
    fn missing_field_errors() {
        let r = depos_from_json(r#"{"depos":[{"t":1.0}]}"#);
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("missing number 'x'"));
    }

    #[test]
    fn optional_fields_default() {
        let r = depos_from_json(r#"{"depos":[{"t":1,"x":2,"y":3,"z":4,"q":5}]}"#).unwrap();
        assert_eq!(r[0].sigma_l, 0.0);
        assert_eq!(r[0].energy, 0.0);
        assert_eq!(r[0].id, 0);
    }

    #[test]
    fn bad_document_errors() {
        assert!(depos_from_json("not json").is_err());
        assert!(depos_from_json("{}").is_err());
        assert!(depos_from_json(r#"{"depos": 3}"#).is_err());
    }

    #[test]
    fn empty_set_roundtrips() {
        let text = depos_to_json(&[]);
        assert_eq!(depos_from_json(&text).unwrap(), vec![]);
    }
}
