//! Synthetic cosmic-ray muon workload generator.
//!
//! Substitute for the paper's CORSIKA + Geant4 + LArSoft chain (see
//! DESIGN.md §2): muons arrive on the top face of the active volume
//! with the classic sea-level cos²θ zenith distribution and a uniform
//! azimuth, then step through the volume leaving Landau-fluctuated MIP
//! depositions.  The produced depo set matches the paper's benchmark
//! workload in the ways the rasterizer cares about: count (~100k for
//! the default event), charge spectrum, spatial clustering along
//! tracks, and arrival-time spread.

use super::{Depo, DepoSource, TrackDepoSource};
use crate::geometry::Detector;
use crate::physics::MipLoss;
use crate::rng::{Pcg32, UniformRng};
use crate::units::*;

/// Cosmic-muon depo source over a detector's active volume.
pub struct CosmicSource {
    /// Detector whose volume tracks must cross.
    pub detector: Detector,
    /// Number of muon tracks per event.
    pub tracks_per_event: usize,
    /// Event time window over which muons arrive uniformly.
    pub window: f64,
    /// Step length for depo creation along each track.
    pub step: f64,
    /// Energy-loss model.
    pub loss: MipLoss,
    /// RNG seed.
    pub seed: u64,
}

impl CosmicSource {
    /// Default readout-window workload for a detector: enough tracks
    /// that one event yields roughly `target_depos` depos.
    pub fn with_target_depos(detector: Detector, target_depos: usize, seed: u64) -> Self {
        // Mean chord length through the volume is ~ the vertical height
        // for steep tracks; estimate depos per track and round up.
        let (lo, hi) = detector.transverse_extent();
        let height = hi - lo;
        let step = 1.0 * MM;
        // tracks exit through the (possibly narrow) drift faces early,
        // so derate the chord estimate by the aspect ratio
        let per_track = ((0.5 * height) / step) as usize;
        let tracks = target_depos.div_ceil(per_track.max(1)).max(1);
        // Arrival window sized so that (generation time + drift time)
        // stays inside the readout for every depo (see `usable_drift`).
        let readout = detector.nticks as f64 * detector.tick;
        Self {
            detector,
            tracks_per_event: tracks,
            window: 0.2 * readout,
            step,
            loss: MipLoss::default(),
            seed,
        }
    }

    /// Largest x a depo may have so its drift ends inside the readout
    /// window given the arrival-time spread.
    fn usable_drift(&self) -> f64 {
        let readout = self.detector.nticks as f64 * self.detector.tick;
        let margin = 0.05 * readout;
        let max_drift_time = (readout - self.window - margin).max(0.0);
        (self.detector.response_plane_x + max_drift_time * self.detector.drift_speed)
            .min(self.detector.max_drift())
    }

    /// Draw a zenith angle from the cos²θ distribution via rejection.
    fn zenith<R: UniformRng>(rng: &mut R) -> f64 {
        loop {
            let theta = rng.uniform() * std::f64::consts::FRAC_PI_2;
            let accept = rng.uniform();
            // pdf ∝ cos²θ sinθ over [0, π/2]
            let p = theta.cos().powi(2) * theta.sin();
            // max of cos²θ·sinθ is ~0.385 at θ≈0.615
            if accept * 0.385 < p {
                return theta;
            }
        }
    }
}

impl DepoSource for CosmicSource {
    fn generate(&mut self) -> Vec<Depo> {
        let mut rng = Pcg32::seeded(self.seed);
        let (tlo, thi) = self.detector.transverse_extent();
        let span = thi - tlo;
        let xmax = self.usable_drift();
        let mut depos = Vec::new();
        for track_id in 0..self.tracks_per_event {
            // Entry point on the top face (y = thi): uniform in x, z.
            let x0 = self.detector.response_plane_x + rng.uniform() * (xmax - self.detector.response_plane_x);
            let z0 = tlo + rng.uniform() * span;
            let y0 = thi;
            let theta = Self::zenith(&mut rng);
            let phi = rng.uniform() * 2.0 * std::f64::consts::PI;
            // Direction pointing downward.
            let dir = [
                theta.sin() * phi.cos(),
                -theta.cos(),
                theta.sin() * phi.sin(),
            ];
            // Track length to exit the volume (bounded by y bottom, x
            // drift range, z extent).
            let mut smax = (y0 - tlo) / -dir[1]; // hits bottom
            if dir[0] > 1e-9 {
                smax = smax.min((xmax - x0) / dir[0]);
            } else if dir[0] < -1e-9 {
                smax = smax.min((self.detector.response_plane_x - x0) / dir[0]);
            }
            if dir[2] > 1e-9 {
                smax = smax.min((thi - z0) / dir[2]);
            } else if dir[2] < -1e-9 {
                smax = smax.min((tlo - z0) / dir[2]);
            }
            if smax <= self.step {
                continue;
            }
            let t0 = rng.uniform() * self.window;
            let mut track = TrackDepoSource {
                start: [x0, y0, z0],
                end: [
                    x0 + smax * dir[0],
                    y0 + smax * dir[1],
                    z0 + smax * dir[2],
                ],
                time: t0,
                step: self.step,
                loss: self.loss.clone(),
                seed: self.seed ^ (track_id as u64).wrapping_mul(0x9e3779b97f4a7c15),
                track_id: track_id as u64,
            };
            depos.extend(track.generate());
        }
        depos
    }

    fn label(&self) -> String {
        format!(
            "cosmic[{} tracks, {:.1} ms window, {} det]",
            self.tracks_per_event,
            self.window / MS,
            self.detector.name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depo::stats;

    #[test]
    fn target_depos_is_roughly_met() {
        let det = Detector::test_small();
        let mut src = CosmicSource::with_target_depos(det, 20_000, 42);
        let depos = src.generate();
        // Zenith-angle spread and early exits make this stochastic;
        // accept a wide band around the target.
        assert!(
            depos.len() > 5_000 && depos.len() < 100_000,
            "got {} depos",
            depos.len()
        );
    }

    #[test]
    fn depos_inside_volume() {
        let det = Detector::test_small();
        let (tlo, thi) = det.transverse_extent();
        let xmax = det.max_drift();
        let rx = det.response_plane_x;
        let mut src = CosmicSource::with_target_depos(det, 5_000, 7);
        let depos = src.generate();
        for d in &depos {
            assert!(d.pos[0] >= rx - 1.0 && d.pos[0] <= xmax + 1.0, "x={}", d.pos[0]);
            assert!(d.pos[1] >= tlo - 1.0 && d.pos[1] <= thi + 1.0, "y={}", d.pos[1]);
            assert!(d.pos[2] >= tlo - 1.0 && d.pos[2] <= thi + 1.0, "z={}", d.pos[2]);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let det = Detector::test_small();
        let d1 = CosmicSource::with_target_depos(det.clone(), 2000, 9).generate();
        let d2 = CosmicSource::with_target_depos(det, 2000, 9).generate();
        assert_eq!(d1.len(), d2.len());
        assert_eq!(stats(&d1), stats(&d2));
    }

    #[test]
    fn different_seeds_differ() {
        let det = Detector::test_small();
        let d1 = CosmicSource::with_target_depos(det.clone(), 2000, 1).generate();
        let d2 = CosmicSource::with_target_depos(det, 2000, 2).generate();
        assert_ne!(stats(&d1).total_charge, stats(&d2).total_charge);
    }

    #[test]
    fn arrival_times_span_window() {
        let det = Detector::test_small();
        let mut src = CosmicSource::with_target_depos(det, 60_000, 3);
        let w = src.window;
        let depos = src.generate();
        let s = stats(&depos);
        assert!(s.time_range.0 >= 0.0);
        assert!(s.time_range.1 <= w * 1.01);
        // spread over at least half the window
        assert!(s.time_range.1 - s.time_range.0 > 0.5 * w);
    }

    #[test]
    fn cosmic_is_bitwise_reproducible() {
        // scenario determinism needs bit-pure generation, not just
        // matching summary stats
        let det = Detector::test_small();
        let a = CosmicSource::with_target_depos(det.clone(), 3000, 5).generate();
        let b = CosmicSource::with_target_depos(det, 3000, 5).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn cosmic_charge_is_mip_scale() {
        // per-depo charge sits in the MIP band the scenario witnesses
        // bound (1 mm steps: thousands of electrons, Landau-tailed)
        let det = Detector::test_small();
        let depos = CosmicSource::with_target_depos(det, 30_000, 13).generate();
        let s = stats(&depos);
        let per_depo = s.total_charge / s.count as f64;
        assert!(
            (2_000.0..25_000.0).contains(&per_depo),
            "per-depo charge {per_depo}"
        );
    }

    #[test]
    fn zenith_angles_prefer_vertical() {
        // cos²θ·sinθ peaks near 35°: steep tracks must dominate over
        // grazing ones.  Sample the generator's own zenith draw.
        let mut rng = crate::rng::Pcg32::seeded(99);
        let n = 4000;
        let steep = (0..n)
            .filter(|_| CosmicSource::zenith(&mut rng) < std::f64::consts::FRAC_PI_4)
            .count();
        // ∫₀^{π/4} cos²θ sinθ dθ / ∫₀^{π/2} ≈ 0.65
        assert!(
            steep > n / 2 && steep < 4 * n / 5,
            "steep fraction {} / {n}",
            steep
        );
    }

    #[test]
    fn tracks_go_downward() {
        // charge-weighted mean y should be above the volume midpoint
        // (tracks enter at the top and may exit the sides early).
        let det = Detector::test_small();
        let mut src = CosmicSource::with_target_depos(det, 10_000, 11);
        let depos = src.generate();
        let s = stats(&depos);
        assert!(s.mean_pos[1] > 0.0, "mean y = {}", s.mean_pos[1]);
    }
}
