//! Prometheus text-exposition building blocks for the serve-mode
//! `/metrics` endpoint.
//!
//! The serve daemon ([`crate::serve`]) exposes its counters, gauges,
//! histograms and latency summaries in the Prometheus text format
//! (version 0.0.4): `# HELP` / `# TYPE` comment pairs followed by
//! `name{labels} value` sample lines.  [`PromText`] renders that
//! format from plain numbers plus the crate's own
//! [`LatencySummary`](super::LatencySummary); [`Histogram`] is a
//! fixed-bucket accumulator that renders as a Prometheus histogram
//! (cumulative `le` buckets plus `_sum` / `_count`); and
//! [`parse_prometheus`] is the minimal scrape-side parser the tests
//! and the ci.sh smoke gate use to assert the endpoint stays
//! machine-readable.

use super::LatencySummary;
use std::collections::BTreeMap;

/// Format one sample value the way Prometheus expects: `f64` display
/// form (shortest round-trip), with the special values spelled the
/// Prometheus way (`+Inf`, `-Inf`, `NaN`).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Fixed-bucket histogram accumulator.
///
/// Buckets are defined by their inclusive upper bounds (ascending);
/// every observation lands in the first bucket whose bound is `>=` the
/// value, or in the implicit `+Inf` overflow bucket.  Rendering is
/// cumulative, as the Prometheus `histogram` type requires.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    overflow: u64,
    sum: f64,
}

impl Histogram {
    /// Histogram over the given ascending upper bounds.
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Self {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len()],
            overflow: 0,
            sum: 0.0,
        }
    }

    /// Log-spaced latency buckets from 100 µs to ~30 s — the default
    /// shape for the serve daemon's service/queue latency histograms.
    pub fn latency_default() -> Self {
        Self::new(&[
            1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0,
        ])
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.sum += v;
        match self.bounds.iter().position(|&b| v <= b) {
            Some(i) => self.counts[i] += 1,
            None => self.overflow += 1,
        }
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.overflow
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative `(upper_bound, count)` pairs, ending with the
    /// `(+Inf, total)` overflow bucket — exactly the sample lines a
    /// Prometheus `histogram` publishes.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        let mut acc = 0;
        for (b, c) in self.bounds.iter().zip(&self.counts) {
            acc += c;
            out.push((*b, acc));
        }
        out.push((f64::INFINITY, acc + self.overflow));
        out
    }
}

/// Prometheus text-format builder: call the typed appenders, then
/// [`render`](Self::render).
///
/// Metric names are the caller's responsibility (use the
/// `wirecell_serve_` prefix for the serve daemon); this type owns the
/// exposition-format details — HELP/TYPE headers, quantile and `le`
/// labels, `_sum` / `_count` series.
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    /// Empty document.
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// Append a counter sample.
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name} {}\n", fmt_value(value)));
    }

    /// Append a counter with one sample line per `(labels, value)`
    /// pair under a shared HELP/TYPE header — e.g.
    /// `sheds_total{path="overrides"} 3`.  Labels are the caller's
    /// verbatim `key="value"` text, without the braces.
    pub fn counter_labeled(&mut self, name: &str, help: &str, samples: &[(&str, f64)]) {
        self.header(name, help, "counter");
        for (labels, value) in samples {
            self.out
                .push_str(&format!("{name}{{{labels}}} {}\n", fmt_value(*value)));
        }
    }

    /// Append a gauge sample.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name} {}\n", fmt_value(value)));
    }

    /// Append a summary with p50/p95/p99 quantiles from a
    /// [`LatencySummary`] (plus the conventional `_sum` / `_count`
    /// series, reconstructed from `mean × n`).
    pub fn summary(&mut self, name: &str, help: &str, lat: &LatencySummary) {
        self.header(name, help, "summary");
        for (q, v) in [
            ("0.5", lat.p50_s),
            ("0.95", lat.p95_s),
            ("0.99", lat.p99_s),
        ] {
            self.out
                .push_str(&format!("{name}{{quantile=\"{q}\"}} {}\n", fmt_value(v)));
        }
        self.out.push_str(&format!(
            "{name}_sum {}\n",
            fmt_value(lat.mean_s * lat.n as f64)
        ));
        self.out.push_str(&format!("{name}_count {}\n", lat.n));
    }

    /// Append a histogram (cumulative `le` buckets, `_sum`, `_count`).
    pub fn histogram(&mut self, name: &str, help: &str, h: &Histogram) {
        self.header(name, help, "histogram");
        for (le, c) in h.cumulative() {
            self.out.push_str(&format!(
                "{name}_bucket{{le=\"{}\"}} {c}\n",
                fmt_value(le)
            ));
        }
        self.out
            .push_str(&format!("{name}_sum {}\n", fmt_value(h.sum())));
        self.out.push_str(&format!("{name}_count {}\n", h.count()));
    }

    /// The rendered exposition document.
    pub fn render(self) -> String {
        self.out
    }
}

/// Minimal scrape-side parser: `name{labels} value` per line, comments
/// and blanks skipped.  Returns samples keyed by the full series name
/// (labels included, verbatim).  Errors on any non-comment line that
/// does not split into a series name and a parseable float — which is
/// exactly the "does the endpoint still emit Prometheus text" gate the
/// tests and ci.sh need.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(char::is_whitespace)
            .ok_or_else(|| format!("line {}: no value in '{line}'", lineno + 1))?;
        let v = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            other => other
                .parse::<f64>()
                .map_err(|_| format!("line {}: bad value '{other}'", lineno + 1))?,
        };
        out.insert(name.trim().to_string(), v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_and_parse() {
        let mut p = PromText::new();
        p.counter("events_total", "Events served", 42.0);
        p.gauge("queue_depth", "Requests waiting", 3.0);
        let text = p.render();
        assert!(text.contains("# TYPE events_total counter"));
        assert!(text.contains("# HELP queue_depth Requests waiting"));
        let m = parse_prometheus(&text).unwrap();
        assert_eq!(m["events_total"], 42.0);
        assert_eq!(m["queue_depth"], 3.0);
    }

    #[test]
    fn labeled_counters_share_one_header() {
        let mut p = PromText::new();
        p.counter_labeled(
            "sheds_total",
            "Requests shed by path",
            &[("path=\"overrides\"", 3.0), ("path=\"hot\"", 0.0)],
        );
        let text = p.render();
        assert_eq!(text.matches("# TYPE sheds_total counter").count(), 1);
        let m = parse_prometheus(&text).unwrap();
        assert_eq!(m["sheds_total{path=\"overrides\"}"], 3.0);
        assert_eq!(m["sheds_total{path=\"hot\"}"], 0.0);
    }

    #[test]
    fn summary_emits_quantiles_sum_and_count() {
        let lat = LatencySummary::from_samples(&[0.1, 0.2, 0.3, 0.4]);
        let mut p = PromText::new();
        p.summary("svc_seconds", "Service latency", &lat);
        let m = parse_prometheus(&p.render()).unwrap();
        assert!((m["svc_seconds{quantile=\"0.5\"}"] - 0.25).abs() < 1e-12);
        assert_eq!(m["svc_seconds_count"], 4.0);
        assert!((m["svc_seconds_sum"] - 1.0).abs() < 1e-12);
        assert!(m["svc_seconds{quantile=\"0.99\"}"] <= 0.4 + 1e-12);
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let mut h = Histogram::new(&[0.1, 1.0]);
        for v in [0.05, 0.5, 0.5, 5.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 6.05).abs() < 1e-12);
        assert_eq!(
            h.cumulative(),
            vec![(0.1, 1), (1.0, 3), (f64::INFINITY, 4)]
        );
        let mut p = PromText::new();
        p.histogram("lat_seconds", "Latency", &h);
        let m = parse_prometheus(&p.render()).unwrap();
        assert_eq!(m["lat_seconds_bucket{le=\"0.1\"}"], 1.0);
        assert_eq!(m["lat_seconds_bucket{le=\"+Inf\"}"], 4.0);
        assert_eq!(m["lat_seconds_count"], 4.0);
    }

    #[test]
    fn default_latency_buckets_cover_the_serving_range() {
        let mut h = Histogram::latency_default();
        h.observe(1e-5); // faster than the first bound -> first bucket
        h.observe(120.0); // slower than the last bound -> +Inf
        let cum = h.cumulative();
        assert_eq!(cum.first().unwrap().1, 1);
        assert_eq!(cum.last().unwrap(), &(f64::INFINITY, 2));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_prometheus("just_a_name\n").is_err());
        assert!(parse_prometheus("name not_a_number\n").is_err());
        // special values parse
        let m = parse_prometheus("a +Inf\nb NaN\n").unwrap();
        assert_eq!(m["a"], f64::INFINITY);
        assert!(m["b"].is_nan());
    }

    #[test]
    fn empty_summary_renders_cleanly() {
        let mut p = PromText::new();
        p.summary("s", "empty", &LatencySummary::default());
        let m = parse_prometheus(&p.render()).unwrap();
        assert_eq!(m["s_count"], 0.0);
        assert_eq!(m["s_sum"], 0.0);
    }
}
