//! Metrics: stage timers, latency quantiles, paper-style table
//! formatting, and Prometheus text exposition (the [`prom`] submodule,
//! re-exported here) for the serve-mode `/metrics` endpoint.

mod prom;

pub use prom::{parse_prometheus, Histogram, PromText};

use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulating named stage timer.
#[derive(Default)]
pub struct StageTimer {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl StageTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    /// Add elapsed seconds to a stage.
    pub fn add(&mut self, stage: &str, seconds: f64) {
        *self.totals.entry(stage.to_string()).or_default() += seconds;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    /// Fold another timer into this one, summing totals and call
    /// counts stage-by-stage.  This is the aggregation step of the
    /// throughput engine: each worker times its own events with a
    /// private `StageTimer`, and the stream report merges them.
    pub fn merge(&mut self, other: &StageTimer) {
        for (stage, secs) in &other.totals {
            *self.totals.entry(stage.clone()).or_default() += secs;
        }
        for (stage, n) in &other.counts {
            *self.counts.entry(stage.clone()).or_default() += n;
        }
    }

    /// Total for one stage.
    pub fn total(&self, stage: &str) -> f64 {
        self.totals.get(stage).copied().unwrap_or(0.0)
    }

    /// Call count for one stage.
    pub fn count(&self, stage: &str) -> u64 {
        self.counts.get(stage).copied().unwrap_or(0)
    }

    /// All stages (name, total seconds, count), insertion-independent
    /// deterministic order.
    pub fn stages(&self) -> Vec<(String, f64, u64)> {
        self.totals
            .iter()
            .map(|(k, &v)| (k.clone(), v, self.counts[k]))
            .collect()
    }

    /// Grand total.
    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }

    /// Zero everything.
    pub fn reset(&mut self) {
        self.totals.clear();
        self.counts.clear();
    }
}

/// Wall-clock rate summary for a multi-event run: the headline numbers
/// of the `throughput` subcommand and bench.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RateStats {
    /// Events completed.
    pub events: u64,
    /// Depos simulated across all events.
    pub depos: u64,
    /// Wall-clock for the whole stream [s].
    pub wall_s: f64,
}

impl RateStats {
    /// Events per second (0 for a zero-duration run).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.events as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Depos per second (0 for a zero-duration run).
    pub fn depos_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.depos as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Percentile of a **pre-sorted** sample set with linear interpolation
/// between closest ranks (the NumPy default): the rank of percentile
/// `p` is `p/100 · (n-1)`, interpolated between the two bracketing
/// samples.  Returns NaN for an empty slice; `p` is clamped to
/// [0, 100].  This is the estimator behind every p50/p95/p99 figure in
/// the throughput reports, pinned against closed-form distributions in
/// this module's tests.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        return sorted[lo];
    }
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Per-event latency summary for a stream run: sample count, mean, the
/// p50/p95/p99 tail quantiles (see [`percentile`]), and the maximum.
/// All values in seconds; reports render them in ms.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    /// Number of latency samples (events).
    pub n: u64,
    /// Mean latency [s].
    pub mean_s: f64,
    /// Median latency [s].
    pub p50_s: f64,
    /// 95th-percentile latency [s].
    pub p95_s: f64,
    /// 99th-percentile latency [s].
    pub p99_s: f64,
    /// Worst-case latency [s].
    pub max_s: f64,
}

impl LatencySummary {
    /// Summarize raw per-event latency samples (any order; a sorted
    /// copy is taken internally).  An empty slice yields the all-zero
    /// default, so reports render cleanly for zero-event runs.
    pub fn from_samples(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Self {
            n: sorted.len() as u64,
            mean_s: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50_s: percentile(&sorted, 50.0),
            p95_s: percentile(&sorted, 95.0),
            p99_s: percentile(&sorted, 99.0),
            max_s: *sorted.last().unwrap(),
        }
    }
}

/// Fixed-width table builder that prints rows like the paper's tables.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: label + seconds columns with 2-decimal formatting.
    pub fn row_seconds(&mut self, label: &str, seconds: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(seconds.iter().map(|s| format!("{s:.3}")));
        self.row(&cells);
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = StageTimer::new();
        t.add("raster", 1.5);
        t.add("raster", 0.5);
        t.add("ft", 0.25);
        assert_eq!(t.total("raster"), 2.0);
        assert_eq!(t.count("raster"), 2);
        assert_eq!(t.total("ft"), 0.25);
        assert_eq!(t.grand_total(), 2.25);
        assert_eq!(t.total("nope"), 0.0);
    }

    #[test]
    fn timer_times_closures() {
        let mut t = StageTimer::new();
        let v = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.total("work") >= 0.004);
    }

    #[test]
    fn timer_merges_stage_by_stage() {
        let mut a = StageTimer::new();
        a.add("raster", 1.0);
        a.add("ft", 0.5);
        let mut b = StageTimer::new();
        b.add("raster", 2.0);
        b.add("raster", 1.0);
        b.add("adc", 0.25);
        a.merge(&b);
        assert_eq!(a.total("raster"), 4.0);
        assert_eq!(a.count("raster"), 3);
        assert_eq!(a.total("ft"), 0.5);
        assert_eq!(a.total("adc"), 0.25);
    }

    #[test]
    fn rate_stats_rates() {
        let r = RateStats {
            events: 20,
            depos: 40_000,
            wall_s: 4.0,
        };
        assert_eq!(r.events_per_sec(), 5.0);
        assert_eq!(r.depos_per_sec(), 10_000.0);
        assert_eq!(RateStats::default().events_per_sec(), 0.0);
    }

    #[test]
    fn timer_reset() {
        let mut t = StageTimer::new();
        t.add("x", 1.0);
        t.reset();
        assert_eq!(t.grand_total(), 0.0);
        assert!(t.stages().is_empty());
    }

    #[test]
    fn percentile_of_constant_distribution_is_the_constant() {
        let s = vec![7.25; 17];
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&s, p), 7.25, "p={p}");
        }
    }

    #[test]
    fn percentile_of_uniform_grid_is_closed_form() {
        // 0, 1, ..., 100: rank(p) = p, so percentile(p) == p exactly
        let s: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        for p in 0..=100 {
            assert_eq!(percentile(&s, p as f64), p as f64, "p={p}");
        }
        // interpolation between grid points is linear
        assert_eq!(percentile(&s, 2.5), 2.5);
        assert_eq!(percentile(&s, 97.5), 97.5);
    }

    #[test]
    fn percentile_of_two_point_distribution_is_closed_form() {
        // 90% zeros, 10% tens (n = 10): rank(p) = 0.09p
        let mut s = vec![0.0; 9];
        s.push(10.0);
        assert_eq!(percentile(&s, 50.0), 0.0);
        assert!((percentile(&s, 95.0) - 5.5).abs() < 1e-12); // rank 8.55
        assert!((percentile(&s, 99.0) - 9.1).abs() < 1e-12); // rank 8.91
        assert_eq!(percentile(&s, 100.0), 10.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // a single sample is every percentile
        let one = [3.5];
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&one, p), 3.5);
        }
        // ties interpolate across the tie boundary
        let ties = [1.0, 1.0, 2.0, 2.0];
        assert_eq!(percentile(&ties, 50.0), 1.5);
        assert_eq!(percentile(&ties, 0.0), 1.0);
        // empty input is NaN, out-of-range p clamps
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&one, -5.0), 3.5);
        assert_eq!(percentile(&one, 150.0), 3.5);
    }

    #[test]
    fn latency_summary_sorts_and_summarizes() {
        // deliberately unsorted input
        let samples = [0.004, 0.001, 0.100, 0.002, 0.003];
        let l = LatencySummary::from_samples(&samples);
        assert_eq!(l.n, 5);
        assert!((l.mean_s - 0.022).abs() < 1e-12);
        assert_eq!(l.p50_s, 0.003);
        assert_eq!(l.max_s, 0.100);
        assert!(l.p95_s <= l.p99_s && l.p99_s <= l.max_s);
        assert!(l.p50_s <= l.p95_s);
        // empty stream renders as the zero default, not NaN
        let empty = LatencySummary::from_samples(&[]);
        assert_eq!(empty, LatencySummary::default());
        assert_eq!(empty.p99_s, 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut tb = Table::new("Table 2", &["Description", "Total [s]", "Fluctuation [s]"]);
        tb.row_seconds("ref-CPU", &[3.57, 3.42]);
        tb.row_seconds("ref-CPU-noRNG", &[0.18, 0.03]);
        let s = tb.render();
        assert!(s.contains("## Table 2"));
        assert!(s.contains("ref-CPU"));
        assert!(s.contains("3.570"));
        assert!(s.lines().count() >= 5);
        assert_eq!(tb.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_rows() {
        let mut tb = Table::new("t", &["a", "b"]);
        tb.row(&["only-one".to_string()]);
    }
}
