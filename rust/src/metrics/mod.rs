//! Metrics: stage timers and paper-style table formatting.

use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulating named stage timer.
#[derive(Default)]
pub struct StageTimer {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl StageTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    /// Add elapsed seconds to a stage.
    pub fn add(&mut self, stage: &str, seconds: f64) {
        *self.totals.entry(stage.to_string()).or_default() += seconds;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    /// Total for one stage.
    pub fn total(&self, stage: &str) -> f64 {
        self.totals.get(stage).copied().unwrap_or(0.0)
    }

    /// Call count for one stage.
    pub fn count(&self, stage: &str) -> u64 {
        self.counts.get(stage).copied().unwrap_or(0)
    }

    /// All stages (name, total seconds, count), insertion-independent
    /// deterministic order.
    pub fn stages(&self) -> Vec<(String, f64, u64)> {
        self.totals
            .iter()
            .map(|(k, &v)| (k.clone(), v, self.counts[k]))
            .collect()
    }

    /// Grand total.
    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }

    /// Zero everything.
    pub fn reset(&mut self) {
        self.totals.clear();
        self.counts.clear();
    }
}

/// Fixed-width table builder that prints rows like the paper's tables.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: label + seconds columns with 2-decimal formatting.
    pub fn row_seconds(&mut self, label: &str, seconds: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(seconds.iter().map(|s| format!("{s:.3}")));
        self.row(&cells);
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = StageTimer::new();
        t.add("raster", 1.5);
        t.add("raster", 0.5);
        t.add("ft", 0.25);
        assert_eq!(t.total("raster"), 2.0);
        assert_eq!(t.count("raster"), 2);
        assert_eq!(t.total("ft"), 0.25);
        assert_eq!(t.grand_total(), 2.25);
        assert_eq!(t.total("nope"), 0.0);
    }

    #[test]
    fn timer_times_closures() {
        let mut t = StageTimer::new();
        let v = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.total("work") >= 0.004);
    }

    #[test]
    fn timer_reset() {
        let mut t = StageTimer::new();
        t.add("x", 1.0);
        t.reset();
        assert_eq!(t.grand_total(), 0.0);
        assert!(t.stages().is_empty());
    }

    #[test]
    fn table_renders_markdown() {
        let mut tb = Table::new("Table 2", &["Description", "Total [s]", "Fluctuation [s]"]);
        tb.row_seconds("ref-CPU", &[3.57, 3.42]);
        tb.row_seconds("ref-CPU-noRNG", &[0.18, 0.03]);
        let s = tb.render();
        assert!(s.contains("## Table 2"));
        assert!(s.contains("ref-CPU"));
        assert!(s.contains("3.570"));
        assert!(s.lines().count() >= 5);
        assert_eq!(tb.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_rows() {
        let mut tb = Table::new("t", &["a", "b"]);
        tb.row(&["only-one".to_string()]);
    }
}
