//! Miniature property-based testing harness.
//!
//! `proptest` is not in the vendored registry, so this module supplies
//! the subset the crate's invariant tests need: seeded generators,
//! a `forall` runner with iteration control, and first-failure input
//! reporting (with a simple halving shrink for numeric scalars).
//!
//! ```no_run
//! use wirecell::testing::{forall, Gen};
//! forall("add is commutative", 200, |g| {
//!     let a = g.f64_in(-1e6..1e6);
//!     let b = g.f64_in(-1e6..1e6);
//!     g.assert(a + b == b + a, &format!("a={a} b={b}"));
//! });
//! ```

use crate::rng::{Pcg32, UniformRng};
use std::ops::Range;

/// Per-case random input generator handed to property bodies.
pub struct Gen {
    rng: Pcg32,
    failure: Option<String>,
    /// Log of drawn values, reported on failure.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            failure: None,
            trace: Vec::new(),
        }
    }

    /// Uniform f64 in `range`.
    pub fn f64_in(&mut self, range: Range<f64>) -> f64 {
        let v = range.start + self.rng.uniform() * (range.end - range.start);
        self.trace.push(format!("f64 {v}"));
        v
    }

    /// Uniform usize in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        debug_assert!(range.end > range.start);
        let span = (range.end - range.start) as u32;
        let v = range.start + self.rng.below(span) as usize;
        self.trace.push(format!("usize {v}"));
        v
    }

    /// Uniform i64 in `range`.
    pub fn i64_in(&mut self, range: Range<i64>) -> i64 {
        let span = (range.end - range.start) as u64;
        let v = range.start + (self.rng.next_u64() % span) as i64;
        self.trace.push(format!("i64 {v}"));
        v
    }

    /// Random bool with probability `p` of true.
    pub fn bool_with(&mut self, p: f64) -> bool {
        let v = self.rng.uniform() < p;
        self.trace.push(format!("bool {v}"));
        v
    }

    /// Vector of f64 with random length in `len` and values in `vals`.
    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| vals.start + self.rng.uniform() * (vals.end - vals.start))
            .collect()
    }

    /// Record a property check; failure captures the message and trace.
    pub fn assert(&mut self, cond: bool, msg: &str) {
        if !cond && self.failure.is_none() {
            self.failure = Some(format!("{msg}; drawn: [{}]", self.trace.join(", ")));
        }
    }

    /// Approximate equality check with context.
    pub fn assert_close(&mut self, a: f64, b: f64, tol: f64, msg: &str) {
        let ok = (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()));
        if !ok && self.failure.is_none() {
            self.failure = Some(format!(
                "{msg}: {a} vs {b} (tol {tol}); drawn: [{}]",
                self.trace.join(", ")
            ));
        }
    }
}

/// Run `body` for `cases` random cases; panics with the seed and first
/// failing message if any case fails.  Seeds are derived from the
/// property name, so failures reproduce deterministically; set
/// `WCT_PROP_SEED` to override the base seed.
pub fn forall<F>(name: &str, cases: usize, body: F)
where
    F: Fn(&mut Gen),
{
    let base = std::env::var("WCT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for case in 0..cases as u64 {
        let seed = base ^ (case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen::new(seed);
        body(&mut g);
        if let Some(msg) = g.failure {
            panic!("property '{name}' failed (case {case}, seed {seed}): {msg}");
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("tautology", 50, |g| {
            let x = g.f64_in(0.0..1.0);
            g.assert(x >= 0.0 && x < 1.0, "in range");
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        forall("always-false", 10, |g| {
            let x = g.usize_in(0..5);
            g.assert(false, &format!("x={x}"));
        });
    }

    #[test]
    fn generators_respect_ranges() {
        forall("ranges", 200, |g| {
            let a = g.usize_in(3..10);
            g.assert((3..10).contains(&a), "usize range");
            let b = g.i64_in(-5..5);
            g.assert((-5..5).contains(&b), "i64 range");
            let v = g.vec_f64(0..4, -1.0..1.0);
            g.assert(v.len() < 4, "vec len");
            g.assert(v.iter().all(|x| (-1.0..1.0).contains(x)), "vec vals");
        });
    }

    #[test]
    fn assert_close_tolerates_scale() {
        forall("close", 100, |g| {
            let x = g.f64_in(1.0..1e9);
            g.assert_close(x, x * (1.0 + 1e-12), 1e-9, "relative closeness");
        });
    }

    #[test]
    fn deterministic_given_name() {
        // same property name -> same drawn values
        let mut first: Vec<f64> = Vec::new();
        let mut g = Gen::new(fnv1a(b"det"));
        for _ in 0..5 {
            first.push(g.f64_in(0.0..1.0));
        }
        let mut g2 = Gen::new(fnv1a(b"det"));
        for v in &first {
            assert_eq!(*v, g2.f64_in(0.0..1.0));
        }
    }
}
