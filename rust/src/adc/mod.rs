//! Digitization: voltage waveform → ADC counts.

/// ADC model: linear conversion with baseline, clamped to the
/// converter's range (12-bit by default, like MicroBooNE).
#[derive(Clone, Debug)]
pub struct Digitizer {
    /// Counts per voltage unit.
    pub counts_per_volt: f64,
    /// Baseline (pedestal) in counts.
    pub baseline: f64,
    /// Number of ADC bits.
    pub bits: u32,
}

impl Digitizer {
    /// MicroBooNE-like 12-bit digitizer: 2 V full scale, pedestal ~2048
    /// for induction planes / ~400 for collection.
    pub fn standard(baseline: f64) -> Self {
        Self {
            counts_per_volt: 4096.0 / 2.0,
            baseline,
            bits: 12,
        }
    }

    /// Max representable count.
    pub fn max_count(&self) -> u16 {
        ((1u32 << self.bits) - 1) as u16
    }

    /// Digitize one sample (voltage in crate base units — the caller
    /// supplies waveforms in volts via `units::VOLT`).
    pub fn digitize(&self, volts: f64) -> u16 {
        let counts = self.baseline + volts * self.counts_per_volt;
        counts.round().clamp(0.0, self.max_count() as f64) as u16
    }

    /// Digitize a full waveform.
    pub fn digitize_wave(&self, wave: &[f64]) -> Vec<u16> {
        wave.iter().map(|&v| self.digitize(v)).collect()
    }

    /// Invert (for analysis/tests): counts → volts relative to baseline.
    pub fn undigitize(&self, counts: u16) -> f64 {
        (counts as f64 - self.baseline) / self.counts_per_volt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_at_zero_volts() {
        let d = Digitizer::standard(2048.0);
        assert_eq!(d.digitize(0.0), 2048);
    }

    #[test]
    fn linear_in_range() {
        let d = Digitizer::standard(400.0);
        let v = 0.1; // volts
        let c = d.digitize(v);
        assert_eq!(c, (400.0f64 + 0.1 * 2048.0).round() as u16);
        // roundtrip within one LSB
        assert!((d.undigitize(c) - v).abs() < 1.0 / d.counts_per_volt);
    }

    #[test]
    fn saturates_high_and_low() {
        let d = Digitizer::standard(2048.0);
        assert_eq!(d.digitize(100.0), 4095);
        assert_eq!(d.digitize(-100.0), 0);
        assert_eq!(d.max_count(), 4095);
    }

    #[test]
    fn wave_digitization() {
        let d = Digitizer::standard(1000.0);
        let wave = vec![0.0, 0.5, -0.25];
        let adc = d.digitize_wave(&wave);
        assert_eq!(adc, vec![1000, 2024, 488]);
    }

    #[test]
    fn negative_swings_preserved_on_induction_baseline() {
        // Induction planes sit mid-range so bipolar signals survive.
        let d = Digitizer::standard(2048.0);
        let lo = d.digitize(-0.5);
        let hi = d.digitize(0.5);
        assert!(lo > 0 && hi < 4095);
        assert_eq!((2048 - lo as i32), (hi as i32 - 2048));
    }
}
